//! The PJRT execution engine.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `compile` → `execute_b`, with
//!
//! * lazy per-artifact compilation (compile once, cached),
//! * shape **bucketing + zero padding** (PJRT shapes are static; the engine
//!   picks the smallest bucket ≥ the live token count and slices the
//!   result),
//! * **device-resident weight buffers**: weights are uploaded once on first
//!   use and passed as `PjRtBuffer`s thereafter; only transient activations
//!   cross host↔device per call (EXPERIMENTS.md §Perf documents the win
//!   over per-call literal uploads).
//!
//! All artifacts were lowered with `return_tuple=True`, so every execution
//! returns a tuple literal that is decomposed here.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::config::Buckets;
use crate::moe::{Manifest, WeightStore};

/// An executable argument: transient host data (uploaded per call) or a
/// named weight (uploaded once, cached on device).
enum Arg {
    Host(Literal),
    Weight(String),
}

/// Lazily-compiling PJRT engine for one model preset.
pub struct PjrtEngine {
    client: PjRtClient,
    manifest: Manifest,
    store: WeightStore,
    exes: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    /// Device-resident weight buffers, uploaded once on first use. The
    /// source literal is kept alive alongside: PJRT's BufferFromHostLiteral
    /// may alias or transfer asynchronously, so the host memory must
    /// outlive the buffer.
    wbufs: RefCell<HashMap<String, (Rc<Literal>, Rc<PjRtBuffer>)>>,
    /// Wall-clock + call-count profiling (perf pass instrumentation).
    pub exec_calls: Cell<u64>,
    pub exec_wall_ns: Cell<u64>,
}

fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if data.len() != n {
        bail!("literal shape {:?} needs {} elems, got {}", dims, n, data.len());
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims_i64)?)
}

fn lit_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims_i64)?)
}

impl PjrtEngine {
    /// Whether a working PJRT CPU client can be created in this build.
    /// False when linked against the offline `xla` stub crate — tests that
    /// need real numerics probe this (plus artifact presence) and skip.
    pub fn pjrt_available() -> bool {
        PjRtClient::cpu().is_ok()
    }

    /// Load `artifacts/<preset>` and start a CPU PJRT client.
    pub fn load(preset: &str) -> Result<Self> {
        let manifest = Manifest::load_preset(preset)?;
        let store = WeightStore::load(&manifest)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(PjrtEngine {
            client,
            manifest,
            store,
            exes: RefCell::new(HashMap::new()),
            wbufs: RefCell::new(HashMap::new()),
            exec_calls: Cell::new(0),
            exec_wall_ns: Cell::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn store(&self) -> &WeightStore {
        &self.store
    }

    /// Compile (or fetch cached) the named artifact.
    fn exe(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.manifest.artifact_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e}"))?,
        );
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Device buffer for a named weight (uploaded once).
    fn weight_buf(&self, name: &str) -> Result<Rc<PjRtBuffer>> {
        if let Some((_, b)) = self.wbufs.borrow().get(name) {
            return Ok(b.clone());
        }
        let t = self.store.get(name)?;
        let lit = Rc::new(lit_f32(&t.data, &t.shape)?);
        let buf = Rc::new(
            self.client
                .buffer_from_host_literal(None, &lit)
                .map_err(|e| anyhow!("uploading weight {name}: {e}"))?,
        );
        self.wbufs.borrow_mut().insert(name.to_string(), (lit, buf.clone()));
        Ok(buf)
    }

    /// Execute an artifact (host args uploaded, weights device-cached) and
    /// decompose the result tuple.
    fn run(&self, name: &str, args: Vec<Arg>) -> Result<Vec<Literal>> {
        let exe = self.exe(name)?;
        let t0 = std::time::Instant::now();
        let mut bufs: Vec<Rc<PjRtBuffer>> = Vec::with_capacity(args.len());
        // Host literals must stay alive until execution completes
        // (BufferFromHostLiteral may alias / transfer asynchronously).
        let mut held: Vec<Literal> = Vec::new();
        for a in args {
            match a {
                Arg::Host(lit) => {
                    bufs.push(Rc::new(
                        self.client
                            .buffer_from_host_literal(None, &lit)
                            .map_err(|e| anyhow!("uploading arg for {name}: {e}"))?,
                    ));
                    held.push(lit);
                }
                Arg::Weight(w) => bufs.push(self.weight_buf(&w)?),
            }
        }
        let refs: Vec<&PjRtBuffer> = bufs.iter().map(|b| b.as_ref()).collect();
        let result =
            exe.execute_b::<&PjRtBuffer>(&refs).map_err(|e| anyhow!("executing {name}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e}"))?;
        drop(held); // safe: to_literal_sync forces completion
        self.exec_calls.set(self.exec_calls.get() + 1);
        self.exec_wall_ns.set(self.exec_wall_ns.get() + t0.elapsed().as_nanos() as u64);
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e}"))
    }

    fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e}"))
    }

    fn bucket_tokens(&self, t: usize) -> Result<usize> {
        let b = Buckets::pick(&self.manifest.buckets.tokens, t);
        if b < t {
            bail!("token count {t} exceeds largest bucket {b}; split the batch");
        }
        Ok(b)
    }

    // --- typed wrappers -----------------------------------------------------

    /// Token + position embedding. `tokens.len() == pos.len() == t`.
    /// Returns `(t, hidden)` row-major.
    pub fn embed(&self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        let d = self.manifest.dims.hidden;
        let t = tokens.len();
        let b = self.bucket_tokens(t)?;
        let mut tk = tokens.to_vec();
        let mut ps = pos.to_vec();
        tk.resize(b, 0);
        ps.resize(b, 0);
        let out = self.run(
            &format!("embed_t{b}"),
            vec![
                Arg::Host(lit_i32(&tk, &[b])?),
                Arg::Host(lit_i32(&ps, &[b])?),
                Arg::Weight("embed.table".into()),
                Arg::Weight("embed.pos".into()),
            ],
        )?;
        let mut x = Self::to_vec_f32(&out[0])?;
        x.truncate(t * d);
        Ok(x)
    }

    /// Fused RMSNorm + gate + softmax for MoE layer `layer` on `t` rows of
    /// `h`. Returns `(probs (t,N), xn (t,d))`.
    pub fn gate(&self, layer: usize, h: &[f32], t: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let d = self.manifest.dims.hidden;
        let n = self.manifest.dims.n_routed;
        let b = self.bucket_tokens(t)?;
        let mut hp = h.to_vec();
        hp.resize(b * d, 0.0);
        let out = self.run(
            &format!("gate_t{b}"),
            vec![
                Arg::Host(lit_f32(&hp, &[b, d])?),
                Arg::Weight(format!("layer.{layer}.moe.norm")),
                Arg::Weight(format!("layer.{layer}.moe.gate")),
            ],
        )?;
        let mut probs = Self::to_vec_f32(&out[0])?;
        let mut xn = Self::to_vec_f32(&out[1])?;
        probs.truncate(t * n);
        xn.truncate(t * d);
        Ok((probs, xn))
    }

    fn expert_inner(&self, w: [String; 3], xn_rows: &[f32], t: usize) -> Result<Vec<f32>> {
        let d = self.manifest.dims.hidden;
        let b = self.bucket_tokens(t)?;
        let mut xp = xn_rows.to_vec();
        xp.resize(b * d, 0.0);
        let [w1, w2, w3] = w;
        let out = self.run(
            &format!("expert_t{b}"),
            vec![
                Arg::Host(lit_f32(&xp, &[b, d])?),
                Arg::Weight(w1),
                Arg::Weight(w2),
                Arg::Weight(w3),
            ],
        )?;
        let mut y = Self::to_vec_f32(&out[0])?;
        y.truncate(t * d);
        Ok(y)
    }

    /// Run routed expert `expert` of `layer` on `t` gathered rows.
    pub fn expert_routed(
        &self,
        layer: usize,
        expert: usize,
        xn_rows: &[f32],
        t: usize,
    ) -> Result<Vec<f32>> {
        self.expert_inner(
            [
                format!("layer.{layer}.moe.expert.{expert}.w1"),
                format!("layer.{layer}.moe.expert.{expert}.w2"),
                format!("layer.{layer}.moe.expert.{expert}.w3"),
            ],
            xn_rows,
            t,
        )
    }

    /// Run shared expert `idx` of `layer` on all `t` rows.
    pub fn expert_shared(
        &self,
        layer: usize,
        idx: usize,
        xn_rows: &[f32],
        t: usize,
    ) -> Result<Vec<f32>> {
        self.expert_inner(
            [
                format!("layer.{layer}.moe.shared.{idx}.w1"),
                format!("layer.{layer}.moe.shared.{idx}.w2"),
                format!("layer.{layer}.moe.shared.{idx}.w3"),
            ],
            xn_rows,
            t,
        )
    }

    fn attn_weight_args(&self, layer: usize) -> Vec<Arg> {
        ["norm", "wq", "wk", "wv", "wo"]
            .into_iter()
            .map(|nm| Arg::Weight(format!("layer.{layer}.attn.{nm}")))
            .collect()
    }

    /// Causal prefill attention for one sequence of `s` tokens.
    /// Returns `(h (s,d), k (s,H,hd), v (s,H,hd))`.
    pub fn attn_prefill(
        &self,
        layer: usize,
        x: &[f32],
        s: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let d = self.manifest.dims.hidden;
        let b = Buckets::pick(&self.manifest.buckets.prefill_seq, s);
        if b < s {
            bail!("prefill length {s} exceeds largest bucket {b}");
        }
        let mut xp = x.to_vec();
        xp.resize(b * d, 0.0);
        let mut args = vec![Arg::Host(lit_f32(&xp, &[b, d])?)];
        args.extend(self.attn_weight_args(layer));
        let out = self.run(&format!("attn_prefill_s{b}"), args)?;
        let heads = self.manifest.dims.heads;
        let hd = self.manifest.dims.head_dim;
        let mut h = Self::to_vec_f32(&out[0])?;
        let mut k = Self::to_vec_f32(&out[1])?;
        let mut v = Self::to_vec_f32(&out[2])?;
        h.truncate(s * d);
        k.truncate(s * heads * hd);
        v.truncate(s * heads * hd);
        Ok((h, k, v))
    }

    /// One decode attention step for `nb` sequences.
    ///
    /// `k_cache`/`v_cache` are `(nb, max_seq, H, hd)` row-major and are
    /// returned updated (new K/V written at each sequence's `pos`).
    pub fn attn_decode(
        &self,
        layer: usize,
        x: &[f32],
        k_cache: &[f32],
        v_cache: &[f32],
        pos: &[i32],
        nb: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let dm = &self.manifest.dims;
        let d = dm.hidden;
        let cache_row = dm.max_seq * dm.heads * dm.head_dim;
        let b = Buckets::pick(&self.manifest.buckets.decode_batch, nb);
        if b < nb {
            bail!("decode batch {nb} exceeds largest bucket {b}; split the batch");
        }
        let mut xp = x.to_vec();
        xp.resize(b * d, 0.0);
        let mut kc = k_cache.to_vec();
        let mut vc = v_cache.to_vec();
        kc.resize(b * cache_row, 0.0);
        vc.resize(b * cache_row, 0.0);
        let mut ps = pos.to_vec();
        ps.resize(b, 0);
        let mut args = vec![
            Arg::Host(lit_f32(&xp, &[b, d])?),
            Arg::Host(lit_f32(&kc, &[b, dm.max_seq, dm.heads, dm.head_dim])?),
            Arg::Host(lit_f32(&vc, &[b, dm.max_seq, dm.heads, dm.head_dim])?),
            Arg::Host(lit_i32(&ps, &[b])?),
        ];
        args.extend(self.attn_weight_args(layer));
        let out = self.run(&format!("attn_decode_b{b}"), args)?;
        let mut h = Self::to_vec_f32(&out[0])?;
        let mut kco = Self::to_vec_f32(&out[1])?;
        let mut vco = Self::to_vec_f32(&out[2])?;
        h.truncate(nb * d);
        kco.truncate(nb * cache_row);
        vco.truncate(nb * cache_row);
        Ok((h, kco, vco))
    }

    /// Final norm + tied LM head on `t` rows. Returns `(t, vocab)` logits.
    pub fn head(&self, h: &[f32], t: usize) -> Result<Vec<f32>> {
        let d = self.manifest.dims.hidden;
        let v = self.manifest.dims.vocab;
        let b = self.bucket_tokens(t)?;
        let mut hp = h.to_vec();
        hp.resize(b * d, 0.0);
        let out = self.run(
            &format!("head_t{b}"),
            vec![
                Arg::Host(lit_f32(&hp, &[b, d])?),
                Arg::Weight("final.norm".into()),
                Arg::Weight("embed.table".into()),
            ],
        )?;
        let mut logits = Self::to_vec_f32(&out[0])?;
        logits.truncate(t * v);
        Ok(logits)
    }
}
