//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! CPU PJRT client. This is the only place real (wall-clock) compute
//! happens on the request path; everything it returns is *numerics* —
//! timing comes from [`crate::hw`].

pub mod engine;

pub use engine::PjrtEngine;
