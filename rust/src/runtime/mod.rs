//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! CPU PJRT client. This is the only place real (wall-clock) compute
//! happens on the request path; everything it returns is *numerics* —
//! timing comes from [`crate::hw`].

pub mod engine;

pub use engine::PjrtEngine;

/// Live-numerics prerequisites: `make artifacts` output + real PJRT
/// bindings. The offline build (xla stub crate, no artifacts) makes tests
/// that need real numerics skip rather than fail.
pub fn live_ready() -> bool {
    let ok = crate::util::artifacts_ready("mixtral-sim") && PjrtEngine::pjrt_available();
    if !ok {
        eprintln!("skipping live test: artifacts/PJRT unavailable in this build");
    }
    ok
}
