//! Run metrics: virtual-time breakdowns, PCIe traffic, cache/prefetch
//! effectiveness. Every experiment in `expt/` reports through this.

pub mod serve;

pub use serve::{percentile_ns, RequestOutcome, RequestStat, ServeReport};

/// Metrics for one inference run (prefill and/or decode).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunMetrics {
    // --- virtual time (ns) ---------------------------------------------------
    /// Total virtual time of the run.
    pub total_ns: u64,
    /// Attention (+ embed/head) time.
    pub attn_ns: u64,
    /// Gate (router) time, excluding prediction gating.
    pub gate_ns: u64,
    /// Extra gating passes executed for prefetch prediction (§6.3-4).
    pub prefetch_gate_ns: u64,
    /// MoE layer makespans (max of CPU side, GPU side per layer).
    pub moe_ns: u64,
    /// Of which: total CPU expert execution time (Eq. 4 sums).
    pub moe_cpu_busy_ns: u64,
    /// Of which: total GPU compute-stream busy time.
    pub moe_gpu_busy_ns: u64,
    /// GPU compute stalls waiting on PCIe transfers.
    pub stall_ns: u64,
    /// Assignment-solve time charged to virtual time (measured wall clock).
    pub sched_ns: u64,
    /// PCIe copy-stream busy time.
    pub pcie_busy_ns: u64,

    // --- PCIe traffic (paper-scale bytes) ------------------------------------
    pub pcie_demand_bytes: u64,
    pub pcie_prefetch_bytes: u64,
    pub pcie_cache_bytes: u64,

    // --- NVMe tier (tiered expert store) --------------------------------------
    /// NVMe read-stream busy time (disk → host promotions).
    pub nvme_read_ns: u64,
    /// NVMe write-stream busy time (host → disk spills with write-back).
    pub nvme_write_ns: u64,
    pub nvme_read_bytes: u64,
    pub nvme_write_bytes: u64,
    /// Disk→host promotions / host→disk spills / GPU→host demotions.
    pub store_promotions: u64,
    pub store_spills: u64,
    pub store_gpu_demotions: u64,

    // --- predictive placement (workload-aware tier placement) ------------------
    /// NVMe→host promotions issued ahead of need from workload predictions.
    pub store_promote_ahead: u64,
    /// Ahead promotions later consumed by an access / spilled unused.
    pub promote_ahead_hits: u64,
    pub promote_ahead_misses: u64,
    /// NVMe read time charged on the demand path (access-time promotions) —
    /// the latency predictive placement exists to remove.
    pub nvme_demand_ns: u64,
    /// NVMe read time of ahead promotions that was already spent when the
    /// expert was consumed: latency hidden behind earlier layers' compute.
    pub nvme_overlap_hidden_ns: u64,

    // --- quantized on-disk format (asymmetric read/transcode tier) -------------
    /// CPU transcode lane busy time: dequantizing promoted experts into
    /// usable host weights (plus re-quantizing spilled ones when
    /// write-back is on). It runs on its own virtual-time lane —
    /// overlapping subsequent NVMe reads — and never occupies the GPU
    /// compute or copy streams.
    pub transcode_ns: u64,
    /// NVMe bytes the quantized on-disk format kept off the link (fp16
    /// bytes minus on-disk bytes, over promotions + write-back spills).
    pub disk_bytes_saved: u64,

    // --- tier hit counters (per executed expert, by weight source) ------------
    /// Executions whose weights were already on the GPU (cache/prefetch).
    pub tier_gpu_hits: u64,
    /// Executions served from host RAM (CPU-run, or PCIe demand fetch).
    pub tier_host_hits: u64,
    /// Executions that had to promote from NVMe first (tier misses).
    pub tier_disk_misses: u64,

    // --- cache / prefetch counters -------------------------------------------
    /// GPU-assigned expert executions that found weights resident.
    pub cache_hits: u64,
    /// GPU-assigned expert executions total.
    pub cache_lookups: u64,
    /// Prefetches issued / that turned out to be used by the next layer.
    pub prefetch_issued: u64,
    pub prefetch_useful: u64,

    // --- work accounting ------------------------------------------------------
    pub tokens_in: u64,
    pub tokens_out: u64,
    pub layer_steps: u64,

    // --- fault injection & degradation (see `crate::fault`) --------------------
    /// Injected-fault NVMe read attempts that timed out and were retried.
    pub fault_retries: u64,
    /// Speculative transfers abandoned after exhausting their retries.
    pub fault_aborts: u64,
    /// NVMe read-lane time consumed by failed (timed-out) attempts — lane
    /// occupancy that moved no usable bytes.
    pub fault_stall_ns: u64,
    /// Virtual time spent inside GPU thermal-throttle windows.
    pub degraded_gpu_ns: u64,
    /// Virtual time spent inside PCIe bandwidth-degradation windows.
    pub degraded_pcie_ns: u64,
    /// Host-RAM pressure transitions (shrink or restore edges) applied.
    pub ram_pressure_events: u64,
    /// Experts demoted under the workload-aware score to satisfy shrinks.
    pub ram_pressure_spills: u64,

    // --- multi-GPU (per device tier; slots past `num_gpus` stay zero) ----------
    /// Expert-cache hits served by each GPU device.
    pub dev_cache_hits: [u64; crate::store::MAX_DEVICES],
    /// GPU compute-stream busy time per device.
    pub dev_compute_busy_ns: [u64; crate::store::MAX_DEVICES],
    /// Demand-path PCIe copy time per device's link.
    pub dev_copy_busy_ns: [u64; crate::store::MAX_DEVICES],
    /// Inter-GPU P2P fabric copies (execution hops + re-homing).
    pub p2p_copies: u64,
    /// Bytes moved over the P2P fabric.
    pub p2p_bytes: u64,
    /// P2P fabric busy time.
    pub p2p_busy_ns: u64,
    /// Store-initiated cross-device expert migrations.
    pub p2p_migrations: u64,

    // --- trace audit -----------------------------------------------------------
    /// Whole-run digest from the trace subsystem's digest sink: an FNV-1a
    /// hash over every emitted scheduling event, in order. `None` under
    /// the default `NullSink` (tracing off). Equal digests ⇔ identical
    /// event streams, so one `u64` locks a whole run in golden tests.
    pub trace_digest: Option<u64>,
}

impl RunMetrics {
    /// Decoding/prefill speed in tokens per simulated second.
    pub fn tokens_per_s(&self) -> f64 {
        if self.total_ns == 0 {
            return 0.0;
        }
        self.tokens_out as f64 / (self.total_ns as f64 / 1e9)
    }

    pub fn cache_hit_rate(&self) -> f64 {
        if self.cache_lookups == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / self.cache_lookups as f64
    }

    pub fn prefetch_accuracy(&self) -> f64 {
        if self.prefetch_issued == 0 {
            return 0.0;
        }
        self.prefetch_useful as f64 / self.prefetch_issued as f64
    }

    pub fn pcie_total_bytes(&self) -> u64 {
        self.pcie_demand_bytes + self.pcie_prefetch_bytes + self.pcie_cache_bytes
    }

    /// Share of total time the PCIe link is busy (paper Fig. 5 metric).
    pub fn pcie_time_share(&self) -> f64 {
        if self.total_ns == 0 {
            return 0.0;
        }
        self.pcie_busy_ns as f64 / self.total_ns as f64
    }

    /// Scheduling overhead relative to end-to-end time (paper Table 6).
    pub fn sched_share(&self) -> f64 {
        if self.total_ns == 0 {
            return 0.0;
        }
        self.sched_ns as f64 / self.total_ns as f64
    }

    /// Total expert executions attributed to a storage tier.
    pub fn tier_lookups(&self) -> u64 {
        self.tier_gpu_hits + self.tier_host_hits + self.tier_disk_misses
    }

    /// Fraction of expert executions that had to promote from NVMe.
    pub fn disk_miss_rate(&self) -> f64 {
        let n = self.tier_lookups();
        if n == 0 {
            return 0.0;
        }
        self.tier_disk_misses as f64 / n as f64
    }

    /// Share of total time the NVMe read stream is busy.
    pub fn nvme_time_share(&self) -> f64 {
        if self.total_ns == 0 {
            return 0.0;
        }
        self.nvme_read_ns as f64 / self.total_ns as f64
    }

    /// Fraction of GPU+host-served expert executions (the complement of
    /// [`Self::disk_miss_rate`]) — what predictive placement maximises.
    pub fn tier_hit_rate(&self) -> f64 {
        let n = self.tier_lookups();
        if n == 0 {
            return 0.0;
        }
        (self.tier_gpu_hits + self.tier_host_hits) as f64 / n as f64
    }

    /// Fraction of ahead promotions that were consumed by an access.
    pub fn promote_ahead_hit_rate(&self) -> f64 {
        if self.store_promote_ahead == 0 {
            return 0.0;
        }
        self.promote_ahead_hits as f64 / self.store_promote_ahead as f64
    }

    /// Accumulate another run's counters (for averaging across batches).
    pub fn merge(&mut self, o: &RunMetrics) {
        self.total_ns += o.total_ns;
        self.attn_ns += o.attn_ns;
        self.gate_ns += o.gate_ns;
        self.prefetch_gate_ns += o.prefetch_gate_ns;
        self.moe_ns += o.moe_ns;
        self.moe_cpu_busy_ns += o.moe_cpu_busy_ns;
        self.moe_gpu_busy_ns += o.moe_gpu_busy_ns;
        self.stall_ns += o.stall_ns;
        self.sched_ns += o.sched_ns;
        self.pcie_busy_ns += o.pcie_busy_ns;
        self.pcie_demand_bytes += o.pcie_demand_bytes;
        self.pcie_prefetch_bytes += o.pcie_prefetch_bytes;
        self.pcie_cache_bytes += o.pcie_cache_bytes;
        self.nvme_read_ns += o.nvme_read_ns;
        self.nvme_write_ns += o.nvme_write_ns;
        self.nvme_read_bytes += o.nvme_read_bytes;
        self.nvme_write_bytes += o.nvme_write_bytes;
        self.store_promotions += o.store_promotions;
        self.store_spills += o.store_spills;
        self.store_gpu_demotions += o.store_gpu_demotions;
        self.store_promote_ahead += o.store_promote_ahead;
        self.promote_ahead_hits += o.promote_ahead_hits;
        self.promote_ahead_misses += o.promote_ahead_misses;
        self.nvme_demand_ns += o.nvme_demand_ns;
        self.nvme_overlap_hidden_ns += o.nvme_overlap_hidden_ns;
        self.transcode_ns += o.transcode_ns;
        self.disk_bytes_saved += o.disk_bytes_saved;
        self.tier_gpu_hits += o.tier_gpu_hits;
        self.tier_host_hits += o.tier_host_hits;
        self.tier_disk_misses += o.tier_disk_misses;
        self.cache_hits += o.cache_hits;
        self.cache_lookups += o.cache_lookups;
        self.prefetch_issued += o.prefetch_issued;
        self.prefetch_useful += o.prefetch_useful;
        self.tokens_in += o.tokens_in;
        self.tokens_out += o.tokens_out;
        self.layer_steps += o.layer_steps;
        self.fault_retries += o.fault_retries;
        self.fault_aborts += o.fault_aborts;
        self.fault_stall_ns += o.fault_stall_ns;
        self.degraded_gpu_ns += o.degraded_gpu_ns;
        self.degraded_pcie_ns += o.degraded_pcie_ns;
        self.ram_pressure_events += o.ram_pressure_events;
        self.ram_pressure_spills += o.ram_pressure_spills;
        for d in 0..crate::store::MAX_DEVICES {
            self.dev_cache_hits[d] += o.dev_cache_hits[d];
            self.dev_compute_busy_ns[d] += o.dev_compute_busy_ns[d];
            self.dev_copy_busy_ns[d] += o.dev_copy_busy_ns[d];
        }
        self.p2p_copies += o.p2p_copies;
        self.p2p_bytes += o.p2p_bytes;
        self.p2p_busy_ns += o.p2p_busy_ns;
        self.p2p_migrations += o.p2p_migrations;
        // Digests are stream hashes, not counters: concatenation order is
        // meaningless for merged runs, so two present digests combine as
        // an order-independent wrapping sum (commutative + associative —
        // parallel and serial sweeps merge to the same value), and a
        // missing digest on either side poisons the merge to `None` (a
        // partial audit is no audit).
        self.trace_digest = match (self.trace_digest, o.trace_digest) {
            (Some(a), Some(b)) => Some(a.wrapping_add(b)),
            _ => None,
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_handle_zero_denominators() {
        let m = RunMetrics::default();
        assert_eq!(m.tokens_per_s(), 0.0);
        assert_eq!(m.cache_hit_rate(), 0.0);
        assert_eq!(m.prefetch_accuracy(), 0.0);
        assert_eq!(m.pcie_time_share(), 0.0);
    }

    #[test]
    fn tokens_per_s_math() {
        let m = RunMetrics { total_ns: 2_000_000_000, tokens_out: 10, ..Default::default() };
        assert!((m.tokens_per_s() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = RunMetrics { total_ns: 10, cache_hits: 1, cache_lookups: 2, ..Default::default() };
        let b = RunMetrics { total_ns: 5, cache_hits: 1, cache_lookups: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.total_ns, 15);
        assert!((a.cache_hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn tier_rates() {
        let m = RunMetrics {
            total_ns: 1_000,
            nvme_read_ns: 250,
            tier_gpu_hits: 2,
            tier_host_hits: 1,
            tier_disk_misses: 1,
            ..Default::default()
        };
        assert_eq!(m.tier_lookups(), 4);
        assert!((m.disk_miss_rate() - 0.25).abs() < 1e-9);
        assert!((m.nvme_time_share() - 0.25).abs() < 1e-9);
        assert_eq!(RunMetrics::default().disk_miss_rate(), 0.0);
    }

    #[test]
    fn merge_accumulates_tier_counters() {
        let mut a = RunMetrics { nvme_read_bytes: 5, store_promotions: 1, ..Default::default() };
        let b = RunMetrics {
            nvme_read_bytes: 7,
            store_promotions: 2,
            store_spills: 3,
            tier_disk_misses: 4,
            store_promote_ahead: 5,
            promote_ahead_hits: 3,
            promote_ahead_misses: 1,
            nvme_demand_ns: 90,
            nvme_overlap_hidden_ns: 40,
            transcode_ns: 25,
            disk_bytes_saved: 11,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.nvme_read_bytes, 12);
        assert_eq!(a.store_promotions, 3);
        assert_eq!(a.store_spills, 3);
        assert_eq!(a.tier_disk_misses, 4);
        assert_eq!(a.store_promote_ahead, 5);
        assert_eq!(a.promote_ahead_hits, 3);
        assert_eq!(a.promote_ahead_misses, 1);
        assert_eq!(a.nvme_demand_ns, 90);
        assert_eq!(a.nvme_overlap_hidden_ns, 40);
        assert_eq!(a.transcode_ns, 25);
        assert_eq!(a.disk_bytes_saved, 11);
    }

    /// Exhaustive-destructure guard: `merge` must support every field.
    ///
    /// The struct literal below names all fields (no `..Default`), the
    /// pattern match binds all fields (no `..` rest), and the assertions
    /// check each one — so adding a counter to `RunMetrics` without
    /// wiring it into `merge` fails to COMPILE here (the PR 5
    /// `transcode_ns` near-miss class), rather than silently merging as
    /// zero. Field k gets value k+1 (all distinct) and the merged result
    /// must be exactly 2·(k+1) for counters; the digest follows its own
    /// documented rule.
    #[test]
    fn merge_supports_every_field_exhaustively() {
        let mk = || RunMetrics {
            total_ns: 1,
            attn_ns: 2,
            gate_ns: 3,
            prefetch_gate_ns: 4,
            moe_ns: 5,
            moe_cpu_busy_ns: 6,
            moe_gpu_busy_ns: 7,
            stall_ns: 8,
            sched_ns: 9,
            pcie_busy_ns: 10,
            pcie_demand_bytes: 11,
            pcie_prefetch_bytes: 12,
            pcie_cache_bytes: 13,
            nvme_read_ns: 14,
            nvme_write_ns: 15,
            nvme_read_bytes: 16,
            nvme_write_bytes: 17,
            store_promotions: 18,
            store_spills: 19,
            store_gpu_demotions: 20,
            store_promote_ahead: 21,
            promote_ahead_hits: 22,
            promote_ahead_misses: 23,
            nvme_demand_ns: 24,
            nvme_overlap_hidden_ns: 25,
            transcode_ns: 26,
            disk_bytes_saved: 27,
            tier_gpu_hits: 28,
            tier_host_hits: 29,
            tier_disk_misses: 30,
            cache_hits: 31,
            cache_lookups: 32,
            prefetch_issued: 33,
            prefetch_useful: 34,
            tokens_in: 35,
            tokens_out: 36,
            layer_steps: 37,
            fault_retries: 38,
            fault_aborts: 39,
            fault_stall_ns: 40,
            degraded_gpu_ns: 41,
            degraded_pcie_ns: 42,
            ram_pressure_events: 43,
            ram_pressure_spills: 44,
            dev_cache_hits: [45; crate::store::MAX_DEVICES],
            dev_compute_busy_ns: [46; crate::store::MAX_DEVICES],
            dev_copy_busy_ns: [47; crate::store::MAX_DEVICES],
            p2p_copies: 48,
            p2p_bytes: 49,
            p2p_busy_ns: 50,
            p2p_migrations: 51,
            trace_digest: Some(0x1000),
        };
        let mut m = mk();
        m.merge(&mk());
        let RunMetrics {
            total_ns,
            attn_ns,
            gate_ns,
            prefetch_gate_ns,
            moe_ns,
            moe_cpu_busy_ns,
            moe_gpu_busy_ns,
            stall_ns,
            sched_ns,
            pcie_busy_ns,
            pcie_demand_bytes,
            pcie_prefetch_bytes,
            pcie_cache_bytes,
            nvme_read_ns,
            nvme_write_ns,
            nvme_read_bytes,
            nvme_write_bytes,
            store_promotions,
            store_spills,
            store_gpu_demotions,
            store_promote_ahead,
            promote_ahead_hits,
            promote_ahead_misses,
            nvme_demand_ns,
            nvme_overlap_hidden_ns,
            transcode_ns,
            disk_bytes_saved,
            tier_gpu_hits,
            tier_host_hits,
            tier_disk_misses,
            cache_hits,
            cache_lookups,
            prefetch_issued,
            prefetch_useful,
            tokens_in,
            tokens_out,
            layer_steps,
            fault_retries,
            fault_aborts,
            fault_stall_ns,
            degraded_gpu_ns,
            degraded_pcie_ns,
            ram_pressure_events,
            ram_pressure_spills,
            dev_cache_hits,
            dev_compute_busy_ns,
            dev_copy_busy_ns,
            p2p_copies,
            p2p_bytes,
            p2p_busy_ns,
            p2p_migrations,
            trace_digest,
        } = m;
        for (i, v) in [
            total_ns,
            attn_ns,
            gate_ns,
            prefetch_gate_ns,
            moe_ns,
            moe_cpu_busy_ns,
            moe_gpu_busy_ns,
            stall_ns,
            sched_ns,
            pcie_busy_ns,
            pcie_demand_bytes,
            pcie_prefetch_bytes,
            pcie_cache_bytes,
            nvme_read_ns,
            nvme_write_ns,
            nvme_read_bytes,
            nvme_write_bytes,
            store_promotions,
            store_spills,
            store_gpu_demotions,
            store_promote_ahead,
            promote_ahead_hits,
            promote_ahead_misses,
            nvme_demand_ns,
            nvme_overlap_hidden_ns,
            transcode_ns,
            disk_bytes_saved,
            tier_gpu_hits,
            tier_host_hits,
            tier_disk_misses,
            cache_hits,
            cache_lookups,
            prefetch_issued,
            prefetch_useful,
            tokens_in,
            tokens_out,
            layer_steps,
            fault_retries,
            fault_aborts,
            fault_stall_ns,
            degraded_gpu_ns,
            degraded_pcie_ns,
            ram_pressure_events,
            ram_pressure_spills,
        ]
        .into_iter()
        .enumerate()
        {
            assert_eq!(v, 2 * (i as u64 + 1), "field #{i} must merge additively");
        }
        assert_eq!(dev_cache_hits, [2 * 45; crate::store::MAX_DEVICES]);
        assert_eq!(dev_compute_busy_ns, [2 * 46; crate::store::MAX_DEVICES]);
        assert_eq!(dev_copy_busy_ns, [2 * 47; crate::store::MAX_DEVICES]);
        assert_eq!(p2p_copies, 2 * 48);
        assert_eq!(p2p_bytes, 2 * 49);
        assert_eq!(p2p_busy_ns, 2 * 50);
        assert_eq!(p2p_migrations, 2 * 51);
        assert_eq!(trace_digest, Some(0x2000), "digests mix as a wrapping sum");
    }

    #[test]
    fn merge_digest_rules() {
        // present + present → order-independent mix (commutative)
        let a = RunMetrics { trace_digest: Some(7), ..Default::default() };
        let b = RunMetrics { trace_digest: Some(u64::MAX), ..Default::default() };
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.trace_digest, Some(6), "wrapping sum");
        assert_eq!(ab.trace_digest, ba.trace_digest, "merge order must not matter");
        // any missing side poisons the merged digest
        let none = RunMetrics::default();
        let mut p = a.clone();
        p.merge(&none);
        assert_eq!(p.trace_digest, None);
        let mut q = none;
        q.merge(&a);
        assert_eq!(q.trace_digest, None);
    }

    #[test]
    fn placement_rates() {
        let m = RunMetrics {
            tier_gpu_hits: 3,
            tier_host_hits: 5,
            tier_disk_misses: 2,
            store_promote_ahead: 4,
            promote_ahead_hits: 3,
            ..Default::default()
        };
        assert!((m.tier_hit_rate() - 0.8).abs() < 1e-9);
        assert!((m.tier_hit_rate() + m.disk_miss_rate() - 1.0).abs() < 1e-9);
        assert!((m.promote_ahead_hit_rate() - 0.75).abs() < 1e-9);
        assert_eq!(RunMetrics::default().tier_hit_rate(), 0.0);
        assert_eq!(RunMetrics::default().promote_ahead_hit_rate(), 0.0);
    }
}
