//! Run metrics: virtual-time breakdowns, PCIe traffic, cache/prefetch
//! effectiveness. Every experiment in `expt/` reports through this.

/// Metrics for one inference run (prefill and/or decode).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    // --- virtual time (ns) ---------------------------------------------------
    /// Total virtual time of the run.
    pub total_ns: u64,
    /// Attention (+ embed/head) time.
    pub attn_ns: u64,
    /// Gate (router) time, excluding prediction gating.
    pub gate_ns: u64,
    /// Extra gating passes executed for prefetch prediction (§6.3-4).
    pub prefetch_gate_ns: u64,
    /// MoE layer makespans (max of CPU side, GPU side per layer).
    pub moe_ns: u64,
    /// Of which: total CPU expert execution time (Eq. 4 sums).
    pub moe_cpu_busy_ns: u64,
    /// Of which: total GPU compute-stream busy time.
    pub moe_gpu_busy_ns: u64,
    /// GPU compute stalls waiting on PCIe transfers.
    pub stall_ns: u64,
    /// Assignment-solve time charged to virtual time (measured wall clock).
    pub sched_ns: u64,
    /// PCIe copy-stream busy time.
    pub pcie_busy_ns: u64,

    // --- PCIe traffic (paper-scale bytes) ------------------------------------
    pub pcie_demand_bytes: u64,
    pub pcie_prefetch_bytes: u64,
    pub pcie_cache_bytes: u64,

    // --- cache / prefetch counters -------------------------------------------
    /// GPU-assigned expert executions that found weights resident.
    pub cache_hits: u64,
    /// GPU-assigned expert executions total.
    pub cache_lookups: u64,
    /// Prefetches issued / that turned out to be used by the next layer.
    pub prefetch_issued: u64,
    pub prefetch_useful: u64,

    // --- work accounting ------------------------------------------------------
    pub tokens_in: u64,
    pub tokens_out: u64,
    pub layer_steps: u64,
}

impl RunMetrics {
    /// Decoding/prefill speed in tokens per simulated second.
    pub fn tokens_per_s(&self) -> f64 {
        if self.total_ns == 0 {
            return 0.0;
        }
        self.tokens_out as f64 / (self.total_ns as f64 / 1e9)
    }

    pub fn cache_hit_rate(&self) -> f64 {
        if self.cache_lookups == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / self.cache_lookups as f64
    }

    pub fn prefetch_accuracy(&self) -> f64 {
        if self.prefetch_issued == 0 {
            return 0.0;
        }
        self.prefetch_useful as f64 / self.prefetch_issued as f64
    }

    pub fn pcie_total_bytes(&self) -> u64 {
        self.pcie_demand_bytes + self.pcie_prefetch_bytes + self.pcie_cache_bytes
    }

    /// Share of total time the PCIe link is busy (paper Fig. 5 metric).
    pub fn pcie_time_share(&self) -> f64 {
        if self.total_ns == 0 {
            return 0.0;
        }
        self.pcie_busy_ns as f64 / self.total_ns as f64
    }

    /// Scheduling overhead relative to end-to-end time (paper Table 6).
    pub fn sched_share(&self) -> f64 {
        if self.total_ns == 0 {
            return 0.0;
        }
        self.sched_ns as f64 / self.total_ns as f64
    }

    /// Accumulate another run's counters (for averaging across batches).
    pub fn merge(&mut self, o: &RunMetrics) {
        self.total_ns += o.total_ns;
        self.attn_ns += o.attn_ns;
        self.gate_ns += o.gate_ns;
        self.prefetch_gate_ns += o.prefetch_gate_ns;
        self.moe_ns += o.moe_ns;
        self.moe_cpu_busy_ns += o.moe_cpu_busy_ns;
        self.moe_gpu_busy_ns += o.moe_gpu_busy_ns;
        self.stall_ns += o.stall_ns;
        self.sched_ns += o.sched_ns;
        self.pcie_busy_ns += o.pcie_busy_ns;
        self.pcie_demand_bytes += o.pcie_demand_bytes;
        self.pcie_prefetch_bytes += o.pcie_prefetch_bytes;
        self.pcie_cache_bytes += o.pcie_cache_bytes;
        self.cache_hits += o.cache_hits;
        self.cache_lookups += o.cache_lookups;
        self.prefetch_issued += o.prefetch_issued;
        self.prefetch_useful += o.prefetch_useful;
        self.tokens_in += o.tokens_in;
        self.tokens_out += o.tokens_out;
        self.layer_steps += o.layer_steps;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_handle_zero_denominators() {
        let m = RunMetrics::default();
        assert_eq!(m.tokens_per_s(), 0.0);
        assert_eq!(m.cache_hit_rate(), 0.0);
        assert_eq!(m.prefetch_accuracy(), 0.0);
        assert_eq!(m.pcie_time_share(), 0.0);
    }

    #[test]
    fn tokens_per_s_math() {
        let m = RunMetrics { total_ns: 2_000_000_000, tokens_out: 10, ..Default::default() };
        assert!((m.tokens_per_s() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = RunMetrics { total_ns: 10, cache_hits: 1, cache_lookups: 2, ..Default::default() };
        let b = RunMetrics { total_ns: 5, cache_hits: 1, cache_lookups: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.total_ns, 15);
        assert!((a.cache_hit_rate() - 0.5).abs() < 1e-9);
    }
}
