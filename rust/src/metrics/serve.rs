//! Per-request serving metrics: TTFT / TPOT samples and their p50/p99
//! aggregation over one serving-simulation run.
//!
//! [`RunMetrics`](super::RunMetrics) stays a flat counter bag for one
//! replay; the serving layer wraps it in a [`ServeReport`] that adds the
//! per-request view — time-to-first-token (arrival → first decode token,
//! queueing and prefill included) and time-per-output-token (the decode
//! cadence after the first token). All math is exact u64 ns, so same-seed
//! reports are bit-identical, and percentiles use the nearest-rank
//! definition (no interpolation — a reported p99 is always a latency some
//! request actually saw).

use crate::hw::Ns;

use super::RunMetrics;

/// How one simulated request left the server.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Generated its full decode budget and retired normally.
    #[default]
    Finished,
    /// Turned away by admission control (never held a batch slot).
    Rejected,
    /// Evicted mid-decode by deadline load-shedding.
    Evicted,
}

impl RequestOutcome {
    pub fn name(self) -> &'static str {
        match self {
            RequestOutcome::Finished => "finished",
            RequestOutcome::Rejected => "rejected",
            RequestOutcome::Evicted => "evicted",
        }
    }
}

/// Lifecycle timestamps of one simulated request (virtual ns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestStat {
    /// When the request entered the arrival queue.
    pub arrival_ns: Ns,
    /// When the continuous batcher admitted it into the running batch.
    pub admit_ns: Ns,
    /// When its first decode token completed.
    pub first_token_ns: Ns,
    /// When its last token completed and it left the batch (for rejected
    /// or evicted requests: when it left, period).
    pub finish_ns: Ns,
    /// Decode tokens generated.
    pub tokens: u64,
    /// Absolute TTFT deadline (`Ns::MAX` = unlimited).
    pub ttft_deadline_ns: Ns,
    /// Absolute completion deadline (`Ns::MAX` = unlimited).
    pub deadline_ns: Ns,
    /// How the request left the server.
    pub outcome: RequestOutcome,
}

impl Default for RequestStat {
    /// Zero timestamps, *unlimited* deadlines: a run that never installs
    /// deadlines scores every finished request as SLO-attained.
    fn default() -> Self {
        RequestStat {
            arrival_ns: 0,
            admit_ns: 0,
            first_token_ns: 0,
            finish_ns: 0,
            tokens: 0,
            ttft_deadline_ns: Ns::MAX,
            deadline_ns: Ns::MAX,
            outcome: RequestOutcome::Finished,
        }
    }
}

impl RequestStat {
    /// Arrival-queue wait (arrival → admission).
    pub fn queue_ns(&self) -> Ns {
        self.admit_ns.saturating_sub(self.arrival_ns)
    }

    /// Time to first token: arrival → first decode token, queue + prefill
    /// + first decode step included.
    pub fn ttft_ns(&self) -> Ns {
        self.first_token_ns.saturating_sub(self.arrival_ns)
    }

    /// Time per output token after the first: the steady decode cadence
    /// (0 for single-token requests, which have no cadence to report).
    pub fn tpot_ns(&self) -> Ns {
        if self.tokens <= 1 {
            return 0;
        }
        self.finish_ns.saturating_sub(self.first_token_ns) / (self.tokens - 1)
    }

    /// SLO attainment: finished normally *and* met both deadlines.
    /// Unlimited deadlines (`Ns::MAX`) are trivially met.
    pub fn attained(&self) -> bool {
        self.outcome == RequestOutcome::Finished
            && self.first_token_ns <= self.ttft_deadline_ns
            && self.finish_ns <= self.deadline_ns
    }
}

/// Nearest-rank percentile of an already-sorted sample (p in [0, 100];
/// p = 0 degenerates to the minimum, p = 100 is the maximum).
/// Returns 0 for an empty sample.
pub fn percentile_ns(sorted: &[Ns], p: f64) -> Ns {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// One serving run's report: per-request SLO aggregates on top of the
/// underlying replay's [`RunMetrics`] (whose `trace_digest` — covering
/// the request-lifecycle events too — is the determinism lock for serve
/// cells).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeReport {
    /// Every request the arrival script produced (finished + rejected +
    /// evicted).
    pub requests: u64,
    /// Requests that generated their full budget and retired normally.
    pub finished: u64,
    /// Requests turned away by admission control.
    pub rejected: u64,
    /// Running requests evicted by deadline load-shedding.
    pub evicted: u64,
    /// Finished requests that also met both of their deadlines.
    pub slo_attained: u64,
    /// Decode tokens generated across all requests (evicted requests'
    /// partial output included).
    pub tokens_out: u64,
    /// Decode tokens from SLO-attained requests only — the tokens a
    /// deadline-bound client actually got value from.
    pub goodput_tokens: u64,
    /// Virtual time spent with the degradation ladder above rung 0.
    pub degraded_ns: Ns,
    /// Virtual time from the run start to the last request's exit.
    pub makespan_ns: Ns,
    /// Percentiles are over *finished* requests (a rejected request has
    /// no TTFT; an evicted one never produced the latency a client saw
    /// to completion) — identical to the historical all-requests values
    /// whenever nothing is rejected or evicted.
    pub ttft_p50_ns: Ns,
    pub ttft_p99_ns: Ns,
    pub tpot_p50_ns: Ns,
    pub tpot_p99_ns: Ns,
    pub queue_p50_ns: Ns,
    pub queue_p99_ns: Ns,
    /// The shared-pipeline replay metrics (cache/store/lane counters and
    /// the whole-run trace digest).
    pub run: RunMetrics,
}

impl ServeReport {
    /// Aggregate per-request stats (order-insensitive: samples are sorted
    /// here) over the finished run's metrics.
    pub fn from_stats(stats: &[RequestStat], run: RunMetrics) -> ServeReport {
        let fin = |s: &&RequestStat| s.outcome == RequestOutcome::Finished;
        let mut ttft: Vec<Ns> = stats.iter().filter(fin).map(|s| s.ttft_ns()).collect();
        let mut tpot: Vec<Ns> = stats
            .iter()
            .filter(fin)
            .filter(|s| s.tokens > 1)
            .map(|s| s.tpot_ns())
            .collect();
        let mut queue: Vec<Ns> = stats.iter().filter(fin).map(|s| s.queue_ns()).collect();
        ttft.sort_unstable();
        tpot.sort_unstable();
        queue.sort_unstable();
        ServeReport {
            requests: stats.len() as u64,
            finished: stats.iter().filter(fin).count() as u64,
            rejected: stats.iter().filter(|s| s.outcome == RequestOutcome::Rejected).count()
                as u64,
            evicted: stats.iter().filter(|s| s.outcome == RequestOutcome::Evicted).count()
                as u64,
            slo_attained: stats.iter().filter(|s| s.attained()).count() as u64,
            tokens_out: stats.iter().map(|s| s.tokens).sum(),
            goodput_tokens: stats.iter().filter(|s| s.attained()).map(|s| s.tokens).sum(),
            degraded_ns: 0,
            makespan_ns: stats.iter().map(|s| s.finish_ns).max().unwrap_or(0),
            ttft_p50_ns: percentile_ns(&ttft, 50.0),
            ttft_p99_ns: percentile_ns(&ttft, 99.0),
            tpot_p50_ns: percentile_ns(&tpot, 50.0),
            tpot_p99_ns: percentile_ns(&tpot, 99.0),
            queue_p50_ns: percentile_ns(&queue, 50.0),
            queue_p99_ns: percentile_ns(&queue, 99.0),
            run,
        }
    }

    /// Serving throughput over the makespan (tokens per virtual second).
    pub fn tokens_per_s(&self) -> f64 {
        if self.makespan_ns == 0 {
            return 0.0;
        }
        self.tokens_out as f64 / (self.makespan_ns as f64 / 1e9)
    }

    /// Fraction of *all* requests that finished within their deadlines —
    /// rejections and evictions count against it, so shedding load only
    /// pays off when it actually rescues the survivors.
    pub fn slo_attainment(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.slo_attained as f64 / self.requests as f64
    }

    /// Goodput over the makespan: deadline-respecting tokens per virtual
    /// second.
    pub fn goodput_per_s(&self) -> f64 {
        if self.makespan_ns == 0 {
            return 0.0;
        }
        self.goodput_tokens as f64 / (self.makespan_ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_percentiles_are_exact() {
        let xs = [10, 20, 30, 40];
        assert_eq!(percentile_ns(&xs, 50.0), 20);
        assert_eq!(percentile_ns(&xs, 75.0), 30);
        assert_eq!(percentile_ns(&xs, 99.0), 40);
        assert_eq!(percentile_ns(&xs, 100.0), 40);
        assert_eq!(percentile_ns(&xs, 1.0), 10);
        assert_eq!(percentile_ns(&[], 50.0), 0);
        assert_eq!(percentile_ns(&[7], 99.0), 7);
    }

    #[test]
    fn request_stat_derives_ttft_tpot_queue() {
        let s = RequestStat {
            arrival_ns: 100,
            admit_ns: 150,
            first_token_ns: 300,
            finish_ns: 900,
            tokens: 4,
            ..RequestStat::default()
        };
        assert_eq!(s.queue_ns(), 50);
        assert_eq!(s.ttft_ns(), 200);
        assert_eq!(s.tpot_ns(), 200); // (900-300)/3
        assert!(s.attained(), "unlimited deadlines are trivially met");
        let single = RequestStat { tokens: 1, ..s };
        assert_eq!(single.tpot_ns(), 0);
        // deadlines bite exactly at the boundary (<= attains, > misses)
        let tight = RequestStat { ttft_deadline_ns: 300, deadline_ns: 900, ..s };
        assert!(tight.attained());
        let late = RequestStat { ttft_deadline_ns: 299, ..tight };
        assert!(!late.attained());
        let over = RequestStat { deadline_ns: 899, ..tight };
        assert!(!over.attained());
        let evicted = RequestStat { outcome: RequestOutcome::Evicted, ..tight };
        assert!(!evicted.attained(), "evicted requests never attain");
    }

    #[test]
    fn report_aggregates_hand_computed_samples() {
        let mk = |arrival, admit, first, finish, tokens| RequestStat {
            arrival_ns: arrival,
            admit_ns: admit,
            first_token_ns: first,
            finish_ns: finish,
            tokens,
            ..RequestStat::default()
        };
        let stats = [
            mk(0, 0, 100, 400, 4),    // ttft 100, tpot 100, queue 0
            mk(50, 100, 350, 950, 4), // ttft 300, tpot 200, queue 50
            mk(60, 200, 260, 260, 1), // ttft 200, no tpot,  queue 140
        ];
        let r = ServeReport::from_stats(&stats, RunMetrics::default());
        assert_eq!(r.requests, 3);
        assert_eq!((r.finished, r.rejected, r.evicted), (3, 0, 0));
        assert_eq!(r.tokens_out, 9);
        assert_eq!(r.makespan_ns, 950);
        assert_eq!(r.ttft_p50_ns, 200);
        assert_eq!(r.ttft_p99_ns, 300);
        assert_eq!(r.tpot_p50_ns, 100); // nearest-rank over {100, 200}
        assert_eq!(r.tpot_p99_ns, 200);
        assert_eq!(r.queue_p50_ns, 50);
        assert_eq!(r.queue_p99_ns, 140);
        assert!((r.tokens_per_s() - 9.0 / (950.0 / 1e9)).abs() < 1e-6);
        // no deadlines installed: everything attains, goodput == output
        assert_eq!(r.slo_attained, 3);
        assert_eq!(r.goodput_tokens, 9);
        assert!((r.slo_attainment() - 1.0).abs() < 1e-12);
        assert!((r.goodput_per_s() - r.tokens_per_s()).abs() < 1e-6);
    }

    // --- satellite: percentile edges -------------------------------------

    #[test]
    fn percentile_edges_n1_p0_p100_and_ties() {
        // n = 1: every percentile is the single sample
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile_ns(&[42], p), 42, "n=1 at p={p}");
        }
        let xs = [10, 20, 30, 40, 50];
        // p = 0 degenerates to the minimum (rank clamps up to 1)
        assert_eq!(percentile_ns(&xs, 0.0), 10);
        // p = 100 is exactly the maximum, never out of bounds
        assert_eq!(percentile_ns(&xs, 100.0), 50);
        // duplicate-value ties: the rank lands inside the tied run and
        // must report the tied value, not a neighbour
        let ties = [5, 5, 5, 5, 9];
        assert_eq!(percentile_ns(&ties, 50.0), 5);
        assert_eq!(percentile_ns(&ties, 80.0), 5);
        assert_eq!(percentile_ns(&ties, 81.0), 9);
        let all_same = [7; 100];
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile_ns(&all_same, p), 7);
        }
        // empty stays 0 at every p (no panic, no NaN-driven rank)
        for p in [0.0, 50.0, 100.0] {
            assert_eq!(percentile_ns(&[], p), 0);
        }
    }

    // --- satellite: an all-rejected run reports cleanly ------------------

    #[test]
    fn all_rejected_report_has_no_nan_or_underflow() {
        let stats = [
            RequestStat {
                arrival_ns: 100,
                finish_ns: 100,
                outcome: RequestOutcome::Rejected,
                ..RequestStat::default()
            },
            RequestStat {
                arrival_ns: 250,
                finish_ns: 250,
                outcome: RequestOutcome::Rejected,
                ..RequestStat::default()
            },
        ];
        let r = ServeReport::from_stats(&stats, RunMetrics::default());
        assert_eq!(r.requests, 2);
        assert_eq!((r.finished, r.rejected, r.evicted), (0, 2, 0));
        assert_eq!((r.slo_attained, r.tokens_out, r.goodput_tokens), (0, 0, 0));
        // percentile samples are empty, not zero-stuffed
        for v in [r.ttft_p50_ns, r.ttft_p99_ns, r.tpot_p50_ns, r.tpot_p99_ns, r.queue_p50_ns, r.queue_p99_ns]
        {
            assert_eq!(v, 0);
        }
        assert_eq!(r.makespan_ns, 250, "makespan covers the last exit");
        assert_eq!(r.slo_attainment(), 0.0);
        assert!(r.goodput_per_s() == 0.0 && r.tokens_per_s() == 0.0);
        assert!(r.slo_attainment().is_finite() && r.goodput_per_s().is_finite());
    }
}
