//! Per-request serving metrics: TTFT / TPOT samples and their p50/p99
//! aggregation over one serving-simulation run.
//!
//! [`RunMetrics`](super::RunMetrics) stays a flat counter bag for one
//! replay; the serving layer wraps it in a [`ServeReport`] that adds the
//! per-request view — time-to-first-token (arrival → first decode token,
//! queueing and prefill included) and time-per-output-token (the decode
//! cadence after the first token). All math is exact u64 ns, so same-seed
//! reports are bit-identical, and percentiles use the nearest-rank
//! definition (no interpolation — a reported p99 is always a latency some
//! request actually saw).

use crate::hw::Ns;

use super::RunMetrics;

/// Lifecycle timestamps of one simulated request (virtual ns).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RequestStat {
    /// When the request entered the arrival queue.
    pub arrival_ns: Ns,
    /// When the continuous batcher admitted it into the running batch.
    pub admit_ns: Ns,
    /// When its first decode token completed.
    pub first_token_ns: Ns,
    /// When its last token completed and it left the batch.
    pub finish_ns: Ns,
    /// Decode tokens generated.
    pub tokens: u64,
}

impl RequestStat {
    /// Arrival-queue wait (arrival → admission).
    pub fn queue_ns(&self) -> Ns {
        self.admit_ns.saturating_sub(self.arrival_ns)
    }

    /// Time to first token: arrival → first decode token, queue + prefill
    /// + first decode step included.
    pub fn ttft_ns(&self) -> Ns {
        self.first_token_ns.saturating_sub(self.arrival_ns)
    }

    /// Time per output token after the first: the steady decode cadence
    /// (0 for single-token requests, which have no cadence to report).
    pub fn tpot_ns(&self) -> Ns {
        if self.tokens <= 1 {
            return 0;
        }
        self.finish_ns.saturating_sub(self.first_token_ns) / (self.tokens - 1)
    }
}

/// Nearest-rank percentile of an already-sorted sample (p in (0, 100]).
/// Returns 0 for an empty sample.
pub fn percentile_ns(sorted: &[Ns], p: f64) -> Ns {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// One serving run's report: per-request SLO aggregates on top of the
/// underlying replay's [`RunMetrics`] (whose `trace_digest` — covering
/// the request-lifecycle events too — is the determinism lock for serve
/// cells).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeReport {
    /// Requests that ran to completion (every request, in this sim).
    pub requests: u64,
    /// Decode tokens generated across all requests.
    pub tokens_out: u64,
    /// Virtual time from the run start to the last request's finish.
    pub makespan_ns: Ns,
    pub ttft_p50_ns: Ns,
    pub ttft_p99_ns: Ns,
    pub tpot_p50_ns: Ns,
    pub tpot_p99_ns: Ns,
    pub queue_p50_ns: Ns,
    pub queue_p99_ns: Ns,
    /// The shared-pipeline replay metrics (cache/store/lane counters and
    /// the whole-run trace digest).
    pub run: RunMetrics,
}

impl ServeReport {
    /// Aggregate per-request stats (order-insensitive: samples are sorted
    /// here) over the finished run's metrics.
    pub fn from_stats(stats: &[RequestStat], run: RunMetrics) -> ServeReport {
        let mut ttft: Vec<Ns> = stats.iter().map(|s| s.ttft_ns()).collect();
        let mut tpot: Vec<Ns> =
            stats.iter().filter(|s| s.tokens > 1).map(|s| s.tpot_ns()).collect();
        let mut queue: Vec<Ns> = stats.iter().map(|s| s.queue_ns()).collect();
        ttft.sort_unstable();
        tpot.sort_unstable();
        queue.sort_unstable();
        ServeReport {
            requests: stats.len() as u64,
            tokens_out: stats.iter().map(|s| s.tokens).sum(),
            makespan_ns: stats.iter().map(|s| s.finish_ns).max().unwrap_or(0),
            ttft_p50_ns: percentile_ns(&ttft, 50.0),
            ttft_p99_ns: percentile_ns(&ttft, 99.0),
            tpot_p50_ns: percentile_ns(&tpot, 50.0),
            tpot_p99_ns: percentile_ns(&tpot, 99.0),
            queue_p50_ns: percentile_ns(&queue, 50.0),
            queue_p99_ns: percentile_ns(&queue, 99.0),
            run,
        }
    }

    /// Serving throughput over the makespan (tokens per virtual second).
    pub fn tokens_per_s(&self) -> f64 {
        if self.makespan_ns == 0 {
            return 0.0;
        }
        self.tokens_out as f64 / (self.makespan_ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_percentiles_are_exact() {
        let xs = [10, 20, 30, 40];
        assert_eq!(percentile_ns(&xs, 50.0), 20);
        assert_eq!(percentile_ns(&xs, 75.0), 30);
        assert_eq!(percentile_ns(&xs, 99.0), 40);
        assert_eq!(percentile_ns(&xs, 100.0), 40);
        assert_eq!(percentile_ns(&xs, 1.0), 10);
        assert_eq!(percentile_ns(&[], 50.0), 0);
        assert_eq!(percentile_ns(&[7], 99.0), 7);
    }

    #[test]
    fn request_stat_derives_ttft_tpot_queue() {
        let s = RequestStat {
            arrival_ns: 100,
            admit_ns: 150,
            first_token_ns: 300,
            finish_ns: 900,
            tokens: 4,
        };
        assert_eq!(s.queue_ns(), 50);
        assert_eq!(s.ttft_ns(), 200);
        assert_eq!(s.tpot_ns(), 200); // (900-300)/3
        let single = RequestStat { tokens: 1, ..s };
        assert_eq!(single.tpot_ns(), 0);
    }

    #[test]
    fn report_aggregates_hand_computed_samples() {
        let mk = |arrival, admit, first, finish, tokens| RequestStat {
            arrival_ns: arrival,
            admit_ns: admit,
            first_token_ns: first,
            finish_ns: finish,
            tokens,
        };
        let stats = [
            mk(0, 0, 100, 400, 4),    // ttft 100, tpot 100, queue 0
            mk(50, 100, 350, 950, 4), // ttft 300, tpot 200, queue 50
            mk(60, 200, 260, 260, 1), // ttft 200, no tpot,  queue 140
        ];
        let r = ServeReport::from_stats(&stats, RunMetrics::default());
        assert_eq!(r.requests, 3);
        assert_eq!(r.tokens_out, 9);
        assert_eq!(r.makespan_ns, 950);
        assert_eq!(r.ttft_p50_ns, 200);
        assert_eq!(r.ttft_p99_ns, 300);
        assert_eq!(r.tpot_p50_ns, 100); // nearest-rank over {100, 200}
        assert_eq!(r.tpot_p99_ns, 200);
        assert_eq!(r.queue_p50_ns, 50);
        assert_eq!(r.queue_p99_ns, 140);
        assert!((r.tokens_per_s() - 9.0 / (950.0 / 1e9)).abs() < 1e-6);
    }
}
