//! Minimal, dependency-free subset of the `anyhow` API (the offline build
//! vendors this instead of fetching the real crate). Covers what this
//! repository uses: `Result`, `Error`, `anyhow!`, `bail!`, `ensure!`, and
//! the `Context` extension trait on `Result` and `Option`.
//!
//! Context is flattened into the message chain (outermost first), so
//! `{e}` and `{e:#}` both render `outer: inner`.

use std::fmt;

/// A string-chained error value. Deliberately does **not** implement
/// `std::error::Error`, so the blanket `From<E: Error>` below stays
/// coherent — same trick the real `anyhow` uses.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context message (outermost-first chain).
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) { $crate::bail!($($arg)*); }
    };
}

/// `anyhow::Context` — attach context to `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::{Context, Result};

    fn fails() -> Result<()> {
        crate::bail!("inner {}", 7)
    }

    #[test]
    fn chain_renders_outer_first() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 7");
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn std_errors_convert() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here/xyz")?;
            Ok(s)
        }
        assert!(io().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");
    }

    #[test]
    fn ensure_macro() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x > 2, "too small: {x}");
            Ok(x)
        }
        assert!(f(1).is_err());
        assert_eq!(f(3).unwrap(), 3);
    }
}
