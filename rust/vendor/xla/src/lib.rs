//! Compile-time stub of the `xla` (xla-rs) PJRT bindings used by the
//! offline build. The container image does not carry the XLA C library, so
//! every entry point that would touch PJRT returns a descriptive runtime
//! error instead; pure-host helpers (`Literal::vec1`, `reshape`) work.
//!
//! Callers that need real numerics (golden tests, the live engine, the
//! serving stack) probe availability first — see
//! `dali::runtime::PjrtEngine::pjrt_available` — and skip gracefully.

use std::fmt;

const UNAVAILABLE: &str =
    "PJRT unavailable: built against the offline xla stub crate (install the real xla-rs \
     bindings and point Cargo at them to run live numerics)";

#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Element types a literal can hold (subset of xla-rs's sealed trait).
pub trait ArrayElement: Copy {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u8 {}

/// Host literal. The stub keeps only the element count for shape checks;
/// data never reaches a device.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    numel: usize,
}

impl Literal {
    pub fn vec1<T: ArrayElement>(data: &[T]) -> Literal {
        Literal { numel: data.len() }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.numel {
            return Err(Error(format!(
                "reshape: literal has {} elements, shape {:?} needs {}",
                self.numel, dims, n
            )));
        }
        Ok(self.clone())
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }
}

#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_checks_numel() {
        let l = Literal::vec1(&[1f32, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn pjrt_entry_points_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
