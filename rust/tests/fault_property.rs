//! Chaos properties for the deterministic fault-injection subsystem.
//!
//! Randomized (but fully seeded) fault profiles are thrown at the tiered
//! replay pipeline and the suite proves the graceful-degradation claims:
//! every faulted run terminates with virtual time advancing (no deadlock —
//! transfers either complete, retry, or abort with a ledger record),
//! residency and budget conservation survive RAM-pressure shrink/restore
//! cycles, latency amplification versus the clean run stays bounded, the
//! same `(fault seed, profile)` pair reproduces the same whole-run trace
//! digest, and a `clean` plan is bit-transparent.

use dali::config::Presets;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::{replay_decode_faulted, Phase, StepSimulator};
use dali::fault::{FaultPlan, FaultProfile};
use dali::hw::CostModel;
use dali::metrics::RunMetrics;
use dali::store::TieredStore;
use dali::trace::DigestSink;
use dali::util::DetRng;
use dali::workload::trace::{synthetic_locality_trace, BatchStep};

/// Run `f` over `n` seeded cases, reporting the failing seed.
fn for_seeds(n: u64, f: impl Fn(u64)) {
    for seed in 0..n {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if result.is_err() {
            panic!("property failed at seed {seed}");
        }
    }
}

/// Build an arbitrary-but-valid profile from a seeded rng: every field
/// stays inside `FaultProfile::validate`'s envelope by construction, and
/// each fault class (read failures, slow reads, GPU/PCIe windows, RAM
/// pressure) is independently present or absent so the conditional
/// accounting checks exercise both sides.
fn random_profile(rng: &mut DetRng) -> FaultProfile {
    let mut p = FaultProfile::clean();
    if rng.chance(0.7) {
        p.nvme_fail_prob = rng.usize_below(61) as f64 / 100.0;
        p.nvme_slow_prob = rng.usize_below(51) as f64 / 100.0;
        p.nvme_slow_mult = 1.0 + rng.usize_below(4) as f64;
        p.max_retries = rng.usize_below(4) as u32;
        p.timeout_mult = 1.0 + rng.usize_below(3) as f64;
        p.backoff_mult = rng.usize_below(3) as f64;
    }
    if rng.chance(0.5) {
        p.gpu_period = 4 + rng.usize_below(24) as u64;
        p.gpu_len = 1 + rng.usize_below(p.gpu_period as usize) as u64;
        p.gpu_mult = 1.0 + (1 + rng.usize_below(30)) as f64 / 10.0;
    }
    if rng.chance(0.5) {
        p.pcie_period = 4 + rng.usize_below(24) as u64;
        p.pcie_len = 1 + rng.usize_below(p.pcie_period as usize) as u64;
        p.pcie_mult = 1.0 + (1 + rng.usize_below(30)) as f64 / 10.0;
    }
    if rng.chance(0.5) {
        p.ram_period = 4 + rng.usize_below(24) as u64;
        p.ram_len = 1 + rng.usize_below(p.ram_period as usize) as u64;
        p.ram_shrink_frac = (1 + rng.usize_below(8)) as f64 / 10.0;
    }
    p.validate().expect("generated profiles are valid by construction");
    p
}

/// DALI replay on `mixtral-sim-ram16` (predictive placement, tiered store)
/// under an optional fault plan, with a digest sink so the returned metrics
/// carry the whole-run event-stream hash.
fn ram16_faulted(faults: Option<FaultPlan>) -> RunMetrics {
    let p = Presets::load_default().unwrap();
    let (model, hw) = p.scenario("mixtral-sim-ram16").unwrap();
    let c = CostModel::new(model, hw);
    let dims = &model.sim;
    let trace = synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 16, 48, 0x7157);
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let cfg = FrameworkCfg::paper_default(dims);
    let bundle = Framework::Dali.bundle(dims, &c, &freq, &cfg);
    let store = TieredStore::for_model(hw, &c, dims.layers, dims.n_routed);
    assert!(!store.is_unlimited());
    let ids: Vec<usize> = (0..8).collect();
    replay_decode_faulted(
        &trace,
        &ids,
        32,
        &c,
        bundle,
        &freq,
        dims.n_shared,
        7,
        faults,
        Some(store),
        DigestSink::new(),
    )
    .0
}

#[test]
fn prop_chaos_runs_terminate_with_conserved_residency() {
    // Arbitrary valid profiles: the run always terminates with the full
    // token count, the store's residency/budget invariants hold after
    // every single step (shrink, spill, restore, retry, abort included),
    // and the fault ledger never invents events a profile cannot cause.
    let p = Presets::load_default().unwrap();
    let (model, hw) = p.scenario("mixtral-sim-ram16").unwrap();
    let c = CostModel::new(model, hw);
    let dims = &model.sim;
    let trace = synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 8, 32, 0x7157);
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let cfg = FrameworkCfg::paper_default(dims);
    let total = dims.layers * dims.n_routed;
    for_seeds(40, |seed| {
        let mut rng = DetRng::new(seed ^ 0xc4a0);
        let profile = random_profile(&mut rng);
        let plan = FaultPlan::new(profile, seed.wrapping_mul(0x9e37_79b9));
        let bundle = Framework::Dali.bundle(dims, &c, &freq, &cfg);
        let store = TieredStore::for_model(hw, &c, dims.layers, dims.n_routed);
        let host_slots = store.host_slots();
        let mut sim = StepSimulator::new(
            &c,
            bundle,
            &freq,
            dims.layers,
            dims.n_routed,
            dims.n_shared,
            7,
        )
        .with_faults(plan)
        .with_store(store);
        let ids: Vec<usize> = (0..6).collect();
        let mut step = BatchStep::default();
        trace.compose_prefill_into(&ids, &mut step);
        sim.run_step(&step, 8, Phase::Prefill);
        sim.reset_metrics();
        for s in 0..trace.min_steps().min(24) {
            trace.compose_decode_into(&ids, s, &mut step);
            sim.run_step(&step, 16 + s, Phase::Decode);
            let st = sim.store().unwrap();
            st.check_invariants().unwrap();
            let (g, h, d) = st.counts();
            assert_eq!(g + h + d, total, "residency must be conserved under faults");
            assert!(g + h <= host_slots, "host budget exceeded under faults");
            assert!(
                st.pressure_reserved() <= host_slots,
                "pressure reservation cannot exceed the budget"
            );
            assert_eq!(st.under_pressure(), st.pressure_reserved() > 0);
        }
        let m = sim.finish();
        assert!(m.tokens_out > 0, "faulted run must still decode");
        assert!(m.total_ns > 0, "virtual time must advance (no deadlock)");
        // The ledger only records events the profile can actually cause.
        if profile.nvme_fail_prob == 0.0 {
            assert_eq!(m.fault_retries, 0, "no failure rate, no retries");
            assert_eq!(m.fault_aborts, 0);
            assert_eq!(m.fault_stall_ns, 0);
        }
        // an abort requires its whole retry budget (≥ 1 logged attempt)
        assert!(m.fault_retries >= m.fault_aborts, "aborts without logged attempts");
        if profile.ram_period == 0 {
            assert_eq!(m.ram_pressure_events, 0);
            assert_eq!(m.ram_pressure_spills, 0);
        }
        if profile.gpu_period == 0 {
            assert_eq!(m.degraded_gpu_ns, 0);
        }
        if profile.pcie_period == 0 {
            assert_eq!(m.degraded_pcie_ns, 0);
        }
    });
}

#[test]
fn prop_ram_pressure_cycles_shrink_and_restore() {
    // A periodic RAM-pressure profile with a 50% on-window must actually
    // fire (the window schedule is pure step arithmetic, not hash-gated),
    // spill down to the shrunken budget inside the window, and restore the
    // full budget outside it — with conservation intact on every step.
    let p = Presets::load_default().unwrap();
    let (model, hw) = p.scenario("mixtral-sim-ram16").unwrap();
    let c = CostModel::new(model, hw);
    let dims = &model.sim;
    let trace = synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 8, 48, 0x7157);
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let cfg = FrameworkCfg::paper_default(dims);
    let total = dims.layers * dims.n_routed;
    let mut profile = FaultProfile::clean();
    profile.ram_period = 8;
    profile.ram_len = 4;
    profile.ram_shrink_frac = 0.5;
    profile.validate().unwrap();
    let bundle = Framework::Dali.bundle(dims, &c, &freq, &cfg);
    let store = TieredStore::for_model(hw, &c, dims.layers, dims.n_routed);
    let host_slots = store.host_slots();
    let mut sim = StepSimulator::new(
        &c,
        bundle,
        &freq,
        dims.layers,
        dims.n_routed,
        dims.n_shared,
        7,
    )
    .with_faults(FaultPlan::new(profile, 0xfa17))
    .with_store(store);
    let ids: Vec<usize> = (0..8).collect();
    let mut step = BatchStep::default();
    trace.compose_prefill_into(&ids, &mut step);
    sim.run_step(&step, 8, Phase::Prefill);
    sim.reset_metrics();
    let mut saw_pressure = false;
    let mut saw_restore = false;
    for s in 0..trace.min_steps().min(40) {
        trace.compose_decode_into(&ids, s, &mut step);
        sim.run_step(&step, 16 + s, Phase::Decode);
        let st = sim.store().unwrap();
        st.check_invariants().unwrap();
        let (g, h, d) = st.counts();
        assert_eq!(g + h + d, total, "shrink/restore must conserve residency");
        assert!(g + h <= host_slots);
        if st.under_pressure() {
            saw_pressure = true;
            assert!(st.pressure_reserved() > 0 && st.pressure_reserved() < host_slots);
        } else if saw_pressure {
            saw_restore = true;
            assert_eq!(st.pressure_reserved(), 0, "budget must restore after the window");
        }
    }
    let m = sim.finish();
    assert!(saw_pressure, "the 4-of-8 pressure window must fire");
    assert!(saw_restore, "the budget must be observed restored between windows");
    assert!(m.ram_pressure_events > 0, "pressure windows must be ledgered");
}

#[test]
fn prop_same_seed_profile_reproduces_the_digest() {
    // Same (profile, fault seed) → identical whole-run trace digest; for a
    // hash-gated profile (read faults consult the seed), varying the fault
    // seed perturbs the injected schedule and therefore the stream.
    let mut boosted = FaultProfile::named("flaky-nvme").unwrap();
    boosted.nvme_fail_prob = 0.5;
    boosted.nvme_slow_prob = 0.5;
    for profile in [
        boosted,
        FaultProfile::named("thermal").unwrap(),
        FaultProfile::named("ram-pressure").unwrap(),
    ] {
        let a = ram16_faulted(Some(FaultPlan::new(profile, 0xfa17)));
        let b = ram16_faulted(Some(FaultPlan::new(profile, 0xfa17)));
        assert!(a.trace_digest.is_some());
        assert_eq!(a, b, "same (seed, profile) must reproduce the run bit-for-bit");
    }
    let digests: Vec<Option<u64>> = (0..6u64)
        .map(|s| ram16_faulted(Some(FaultPlan::new(boosted, s))).trace_digest)
        .collect();
    let mut uniq = digests.clone();
    uniq.sort_unstable();
    uniq.dedup();
    assert!(
        uniq.len() >= 2,
        "fault seeds must perturb the injected schedule: {digests:?}"
    );
}

#[test]
fn prop_latency_amplification_is_bounded() {
    // Faults slow runs down but never unboundedly: each named profile's
    // per-op amplification is capped (timeout ≤ timeout_mult × read, at
    // most max_retries + 1 attempts, window mults ≤ 2, shrink ≤ 65%), so
    // whole-run latency stays within a generous constant of clean — the
    // "graceful" in graceful degradation. The lower bound guards against
    // accounting bugs that would make a faulted run impossibly fast.
    let p = Presets::load_default().unwrap();
    let clean = ram16_faulted(None);
    assert!(clean.total_ns > 0);
    for name in ["flaky-nvme", "thermal", "ram-pressure"] {
        let plan = FaultPlan::new(p.fault_profile(name).unwrap(), 0xfa17);
        let faulted = ram16_faulted(Some(plan));
        assert!(faulted.tokens_out == clean.tokens_out, "{name}: same work must complete");
        let ratio = faulted.total_ns as f64 / clean.total_ns as f64;
        assert!(
            ratio <= 25.0,
            "{name}: latency amplification must stay bounded, got {ratio:.2}x"
        );
        assert!(
            ratio >= 0.5,
            "{name}: faulted runs cannot be dramatically faster than clean, got {ratio:.2}x"
        );
    }
}

#[test]
fn clean_plan_is_bit_transparent() {
    // `--faults clean` must be indistinguishable — metrics and digest —
    // from never installing a plan at all.
    let unfaulted = ram16_faulted(None);
    let clean = ram16_faulted(Some(FaultPlan::new(FaultProfile::clean(), 0xfa17)));
    assert_eq!(clean, unfaulted, "clean plan must be bit-transparent");
    assert_eq!(clean.fault_retries, 0);
    assert_eq!(clean.ram_pressure_events, 0);
    assert_eq!(clean.degraded_gpu_ns, 0);
    assert_eq!(clean.degraded_pcie_ns, 0);
}
