//! Integration: the rust engine's full forward pass must reproduce the
//! python reference (`golden.json`, written by `compile/aot.py`) —
//! routing decisions exactly, logits to float tolerance.
//!
//! Requires `make artifacts`.

use dali::coordinator::engine::InferenceEngine;
use dali::moe::Manifest;
use dali::util::json::Value;

fn load_golden(preset: &str) -> (Value, InferenceEngine) {
    let m = Manifest::load_preset(preset).expect("run `make artifacts` first");
    let text = std::fs::read_to_string(m.golden_path()).unwrap();
    let golden = Value::parse(&text).unwrap();
    let eng = InferenceEngine::new(preset).unwrap();
    (golden, eng)
}

fn check_preset(preset: &str) {
    let (golden, eng) = load_golden(preset);
    let prompts: Vec<Vec<i32>> = golden
        .get("prompts")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| p.as_usize_vec().unwrap().into_iter().map(|t| t as i32).collect())
        .collect();
    let steps = golden.get("decode_steps").unwrap().as_usize().unwrap();
    let out = eng.run_batch(&prompts, steps, false).unwrap();

    let seqs = golden.get("sequences").unwrap().as_arr().unwrap();
    for (si, seq) in seqs.iter().enumerate() {
        // --- prefill routing must match exactly -----------------------------
        let routes = seq.get("prefill_routes").unwrap().as_arr().unwrap();
        for (l, layer_routes) in routes.iter().enumerate() {
            for (t, tok_routes) in layer_routes.as_arr().unwrap().iter().enumerate() {
                let want = tok_routes.as_usize_vec().unwrap();
                let got = &out.prefill_routes[si][t][l];
                assert_eq!(got, &want, "prefill route mismatch seq {si} layer {l} tok {t}");
            }
        }
        // --- prefill last-token logits ---------------------------------------
        let want8 = seq.get("prefill_last_logits8").unwrap().as_f32_vec().unwrap();
        for (i, &w) in want8.iter().enumerate() {
            let g = out.prefill_last_logits[si][i];
            assert!(
                (g - w).abs() < 3e-3,
                "prefill logit {i} seq {si}: got {g}, want {w}"
            );
        }
        // --- decode steps ------------------------------------------------------
        let decode = seq.get("decode").unwrap().as_arr().unwrap();
        for (di, step) in decode.iter().enumerate() {
            let want_routes = step.get("routes").unwrap().as_arr().unwrap();
            for (l, r) in want_routes.iter().enumerate() {
                let want = r.as_usize_vec().unwrap();
                let got = &out.decode_routes[si][di][l];
                assert_eq!(got, &want, "decode route mismatch seq {si} step {di} layer {l}");
            }
            let want8 = step.get("logits8").unwrap().as_f32_vec().unwrap();
            for (i, &w) in want8.iter().enumerate() {
                let g = out.decode_logits[si][di][i];
                assert!(
                    (g - w).abs() < 3e-3,
                    "decode logit seq {si} step {di} idx {i}: got {g}, want {w}"
                );
            }
            let want_tok = step.get("argmax").unwrap().as_usize().unwrap() as i32;
            assert_eq!(out.generated[si][di], want_tok, "token mismatch seq {si} step {di}");
        }
    }
}


/// Shared skip probe — see `dali::runtime::live_ready`.
fn live_ready() -> bool {
    dali::runtime::live_ready()
}

#[test]
fn golden_mixtral() {
    if !live_ready() {
        return;
    }
    check_preset("mixtral-sim");
}

#[test]
fn golden_deepseek_shared_experts() {
    if !live_ready() {
        return;
    }
    // deepseek-sim exercises the shared-expert path (n_shared = 1)
    check_preset("deepseek-sim");
}

#[test]
fn golden_qwen() {
    if !live_ready() {
        return;
    }
    check_preset("qwen-sim");
}
