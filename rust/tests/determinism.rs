//! Determinism + scratch-reuse equivalence for the simulator hot path.
//!
//! The zero-allocation refactor must be *observably invisible*: replaying
//! the same preset + seed twice yields field-for-field identical
//! [`RunMetrics`] (the modeled solve cost removed the wall-clock
//! nondeterminism), running sweep cells under `--jobs 4` vs serial changes
//! nothing, and the buffer-reusing replay path is bit-identical to a naive
//! reference implementation (fresh allocations every step) kept here.

use dali::config::Presets;
use dali::coordinator::assignment::{GreedyAssigner, SolveCost};
use dali::coordinator::cache::WorkloadAwareCache;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::prefetch::ResidualPrefetcher;
use dali::coordinator::simrun::{
    replay_decode, replay_decode_faulted, replay_decode_gpus, replay_decode_store, Phase,
    PolicyBundle, StepSimulator,
};
use dali::fault::FaultPlan;
use dali::hw::CostModel;
use dali::metrics::RunMetrics;
use dali::store::{PlacementCfg, TieredStore};
use dali::trace::DigestSink;
use dali::util::pool::parallel_map;
use dali::workload::trace::{synthetic_locality_trace, Trace};

const LAYERS_SEED: u64 = 0xbe7c;

fn cost(model: &str) -> CostModel {
    let p = Presets::load_default().unwrap();
    CostModel::new(p.model(model).unwrap(), p.hw("local-pc").unwrap())
}

fn dali_bundle(layers: usize, n: usize) -> PolicyBundle {
    PolicyBundle {
        assigner: Box::new(GreedyAssigner::new()),
        prefetcher: Box::new(ResidualPrefetcher),
        cache: Box::new(WorkloadAwareCache::new(layers, n, (n / 2).max(1), 4, 1, 17)),
        prefetch_size: 1,
        cpu_eff: 1.0,
        layer_overhead_ns: 0,
        gpu_free_slots: n,
        solve_cost: SolveCost::Modeled,
        placement: PlacementCfg::default(),
    }
}

fn trace_for(layers: usize, n: usize) -> Trace {
    synthetic_locality_trace(layers, n, 2, 8, 40, LAYERS_SEED)
}

#[test]
fn identical_seed_replays_are_bit_identical() {
    // Acceptance criterion: two identical-seed replays produce
    // field-for-field identical RunMetrics with the default (modeled)
    // solve cost — RunMetrics derives PartialEq over every field.
    let c = cost("mixtral-sim");
    let t = trace_for(4, 8);
    let freq = vec![vec![0.0; 8]; 4];
    let ids: Vec<usize> = (0..6).collect();
    let run = || replay_decode(&t, &ids, 32, &c, dali_bundle(4, 8), &freq, 1, 7);
    let a = run();
    let b = run();
    assert_eq!(a, b, "same preset + seed must replay bit-identically");
    assert!(a.tokens_out > 0 && a.sched_ns > 0);
}

#[test]
fn default_solve_cost_is_modeled() {
    // The determinism guarantee holds only because Modeled is the default.
    assert_eq!(SolveCost::default(), SolveCost::Modeled);
    let b = dali_bundle(2, 8);
    assert_eq!(b.solve_cost, SolveCost::Modeled);
}

#[test]
fn parallel_sweep_matches_serial_sweep() {
    // `--jobs 4` vs serial: the same cells produce field-for-field
    // identical metrics regardless of worker threads.
    let c = cost("mixtral-sim");
    let t = trace_for(4, 8);
    let freq = vec![vec![0.0; 8]; 4];
    let cells: Vec<(usize, u64)> =
        vec![(2, 1), (4, 7), (6, 7), (8, 13), (4, 99), (2, 42), (8, 7), (6, 1)];
    let run_cell = |(batch, seed): (usize, u64)| -> RunMetrics {
        let ids: Vec<usize> = (0..batch).collect();
        replay_decode(&t, &ids, 24, &c, dali_bundle(4, 8), &freq, 1, seed)
    };
    let serial = parallel_map(1, cells.clone(), run_cell);
    let par = parallel_map(4, cells, run_cell);
    assert_eq!(serial, par, "--jobs must never change reported metrics");
}

#[test]
fn scratch_reuse_matches_naive_reference_replay() {
    // Reference implementation (the pre-refactor shape): compose a FRESH
    // BatchStep for every decode step via the allocating API and feed it to
    // the simulator. The library's replay_decode instead reuses one buffer
    // through compose_decode_into and the simulator's internal scratch.
    // Both must produce bit-identical metrics.
    for (model, layers, n) in [("mixtral-sim", 4usize, 8usize), ("deepseek-sim", 4, 16)] {
        let c = cost(model);
        let t = synthetic_locality_trace(layers, n, 2, 8, 40, LAYERS_SEED);
        let freq = vec![vec![0.0; n]; layers];
        let ids: Vec<usize> = (0..6).collect();
        let steps = 32usize;

        // naive reference, kept deliberately allocation-heavy
        let naive = {
            let mut sim =
                StepSimulator::new(&c, dali_bundle(layers, n), &freq, layers, n, 1, 7);
            let prompt_len = t.seqs[ids[0] % t.seqs.len()].prompt_len;
            let prefill = t.compose_prefill(&ids);
            sim.run_step(&prefill, prompt_len / 2, Phase::Prefill);
            sim.reset_metrics();
            for s in 0..steps.min(t.min_steps()) {
                let step = t.compose_decode(&ids, s); // fresh allocation
                sim.run_step(&step, prompt_len + s, Phase::Decode);
            }
            sim.finish()
        };

        let reused = replay_decode(&t, &ids, steps, &c, dali_bundle(layers, n), &freq, 1, 7);
        assert_eq!(reused, naive, "{model}: scratch reuse must be bit-identical");
    }
}

#[test]
fn memory_limited_store_replays_are_bit_identical() {
    // The placement subsystem (EWMA scores, promote-ahead, arrival table)
    // must preserve the determinism guarantee: same seed + same store
    // budget → field-for-field identical RunMetrics, predictive or not —
    // and with the quantized on-disk format (read + transcode lanes) just
    // the same.
    let p = Presets::load_default().unwrap();
    for scenario in ["mixtral-sim-ram16", "mixtral-sim-ram16-q4"] {
        let (model, hw) = p.scenario(scenario).unwrap();
        let c = CostModel::for_scenario(&p, scenario).unwrap();
        let dims = &model.sim;
        let t =
            synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 8, 40, LAYERS_SEED);
        let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
        let ids: Vec<usize> = (0..6).collect();
        for predictive in [false, true] {
            let run = || {
                let mut bundle = dali_bundle(dims.layers, dims.n_routed);
                if predictive {
                    bundle.placement = PlacementCfg::predictive(1);
                }
                let store = TieredStore::for_model(hw, &c, dims.layers, dims.n_routed);
                replay_decode_store(&t, &ids, 32, &c, bundle, &freq, 1, 7, Some(store))
            };
            let a = run();
            assert_eq!(
                a,
                run(),
                "{scenario} predictive={predictive}: store replays must be bit-identical"
            );
            assert!(a.tier_disk_misses + a.store_promote_ahead > 0, "store must be exercised");
        }
    }
}

#[test]
fn ram_sweep_cells_parallel_match_serial() {
    // The `expt ram` sweep shape — (hardware budget × placement × seed)
    // cells over a shared traced workload — must report identical numbers
    // under `--jobs 4` and serial execution.
    let p = Presets::load_default().unwrap();
    let model = p.model("mixtral-sim").unwrap();
    let dims = &model.sim;
    let t = synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 8, 32, LAYERS_SEED);
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let mut cells: Vec<(&str, bool, u64)> = Vec::new();
    for hw_name in ["local-pc", "local-pc-ram16", "local-pc-ram8"] {
        for predictive in [false, true] {
            for seed in [7u64, 13] {
                cells.push((hw_name, predictive, seed));
            }
        }
    }
    let run_cell = |(hw_name, predictive, seed): (&str, bool, u64)| -> RunMetrics {
        let hw = p.hw(hw_name).unwrap();
        let c = CostModel::new(model, hw);
        let mut bundle = dali_bundle(dims.layers, dims.n_routed);
        if predictive {
            bundle.placement = PlacementCfg::predictive(1);
        }
        let store = TieredStore::for_model(hw, &c, dims.layers, dims.n_routed);
        let ids: Vec<usize> = (0..6).collect();
        replay_decode_store(&t, &ids, 24, &c, bundle, &freq, 1, seed, Some(store))
    };
    let serial = parallel_map(1, cells.clone(), run_cell);
    let par = parallel_map(4, cells, run_cell);
    assert_eq!(serial, par, "--jobs must never change ram-sweep metrics");
}

#[test]
fn faulted_store_replays_are_bit_identical() {
    // The fault-injection acceptance criterion: `mixtral-sim-ram16-q4`
    // under the `flaky-nvme` profile replays bit-identically — RunMetrics
    // field-for-field equal INCLUDING `trace_digest` (DigestSink hashes
    // every event, so equality here means the whole event stream matched,
    // retries and backoff stalls included). A clean plan must be bit-
    // transparent: identical to running with no plan installed at all.
    let p = Presets::load_default().unwrap();
    let scenario = "mixtral-sim-ram16-q4";
    let (model, hw) = p.scenario(scenario).unwrap();
    let c = CostModel::for_scenario(&p, scenario).unwrap();
    let dims = &model.sim;
    let t = synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 8, 48, LAYERS_SEED);
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let ids: Vec<usize> = (0..6).collect();
    let run = |faults: Option<FaultPlan>| {
        let mut bundle = dali_bundle(dims.layers, dims.n_routed);
        bundle.placement = PlacementCfg::predictive(1);
        let store = TieredStore::for_model(hw, &c, dims.layers, dims.n_routed);
        replay_decode_faulted(
            &t,
            &ids,
            32,
            &c,
            bundle,
            &freq,
            1,
            7,
            faults,
            Some(store),
            DigestSink::new(),
        )
        .0
    };

    let flaky = FaultPlan::new(p.fault_profile("flaky-nvme").unwrap(), 0xfa17);
    let a = run(Some(flaky));
    let b = run(Some(flaky));
    assert!(a.trace_digest.is_some(), "digest sink must surface a digest");
    assert_eq!(a, b, "same (seed, profile) must replay bit-identically, digest included");

    // A boosted failure rate makes retries a certainty on this workload
    // (named flaky-nvme's 8% per-read rate is near-certain but not provable
    // without running it, so the hard assertion uses the boosted spec).
    let boosted = run(Some(FaultPlan::new(
        p.fault_profile("nvme_fail_prob=0.5,nvme_slow_prob=0.5,nvme_slow_mult=4").unwrap(),
        0xfa17,
    )));
    let unfaulted = run(None);
    assert!(boosted.fault_retries > 0, "boosted profile must inject read failures");
    assert!(boosted.fault_stall_ns > 0, "failed attempts must charge stall time");
    assert_ne!(
        boosted.trace_digest, unfaulted.trace_digest,
        "FaultRetry events must perturb the event stream"
    );

    // clean plan == no plan, bit for bit
    let clean = FaultPlan::new(p.fault_profile("clean").unwrap(), 0xfa17);
    assert_eq!(
        run(Some(clean)),
        unfaulted,
        "--faults clean must be bit-identical to the un-faulted replay"
    );
}

#[test]
fn multi_gpu_replays_are_deterministic_and_one_gpu_is_transparent() {
    // The expert-parallel backcompat lock, dynamic half: `num_gpus = 1`
    // through the generalized N-device entry point is bit-identical —
    // digest included — to the legacy single-GPU replay (the static half
    // is tests/golden/run_digests.json, blessed before the multi-device
    // refactor and still asserted by trace_digest.rs). A 2-device replay
    // must itself be bit-deterministic, and sharding must genuinely
    // perturb the event stream (different digest from 1 GPU).
    let p = Presets::load_default().unwrap();
    let scenario = "mixtral-sim-ram16-q4";
    let (model, hw) = p.scenario(scenario).unwrap();
    let c = CostModel::for_scenario(&p, scenario).unwrap();
    let dims = &model.sim;
    let t = synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 8, 48, LAYERS_SEED);
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let ids: Vec<usize> = (0..6).collect();
    let run = |gpus: usize| {
        let mut bundle = dali_bundle(dims.layers, dims.n_routed);
        bundle.placement = PlacementCfg::predictive(1);
        let store = TieredStore::for_model(hw, &c, dims.layers, dims.n_routed);
        replay_decode_gpus(
            &t,
            &ids,
            32,
            &c,
            bundle,
            &freq,
            1,
            7,
            gpus,
            None,
            Some(store),
            DigestSink::new(),
        )
        .0
    };
    let legacy = {
        let mut bundle = dali_bundle(dims.layers, dims.n_routed);
        bundle.placement = PlacementCfg::predictive(1);
        let store = TieredStore::for_model(hw, &c, dims.layers, dims.n_routed);
        replay_decode_faulted(
            &t,
            &ids,
            32,
            &c,
            bundle,
            &freq,
            1,
            7,
            None,
            Some(store),
            DigestSink::new(),
        )
        .0
    };
    let one = run(1);
    assert_eq!(one, legacy, "n_gpus = 1 must be the single-GPU replay, bit for bit");
    let two_a = run(2);
    let two_b = run(2);
    assert_eq!(two_a, two_b, "2-GPU replays must be bit-identical, digest included");
    assert!(two_a.trace_digest.is_some() && one.trace_digest.is_some());
    assert_ne!(
        two_a.trace_digest, one.trace_digest,
        "device sharding must perturb the event stream"
    );
}

#[test]
fn framework_bundles_replay_deterministically() {
    // Every comparison-set bundle (not just DALI's) is covered by the
    // modeled-solve-cost guarantee.
    let p = Presets::load_default().unwrap();
    let model = p.model("mixtral-sim").unwrap();
    let c = CostModel::new(model, p.hw("local-pc").unwrap());
    let dims = &model.sim;
    let t = synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 8, 24, 0x51ee);
    let freq = vec![vec![0.1; dims.n_routed]; dims.layers];
    let cfg = FrameworkCfg::paper_default(dims);
    let ids: Vec<usize> = (0..4).collect();
    for fw in Framework::comparison_set() {
        let run = || {
            let bundle = fw.bundle(dims, &c, &freq, &cfg);
            replay_decode(&t, &ids, 16, &c, bundle, &freq, dims.n_shared, 11)
        };
        assert_eq!(run(), run(), "{} must replay deterministically", fw.name());
    }
    // and with a memory-limited store attached (placement active for DALI,
    // reactive for the baselines) the guarantee still holds per bundle
    let hw16 = p.hw("local-pc-ram16").unwrap();
    let c16 = CostModel::new(model, hw16);
    for fw in Framework::comparison_set() {
        let run = || {
            let bundle = fw.bundle(dims, &c16, &freq, &cfg);
            let store = TieredStore::for_model(hw16, &c16, dims.layers, dims.n_routed);
            replay_decode_store(&t, &ids, 16, &c16, bundle, &freq, dims.n_shared, 11, Some(store))
        };
        assert_eq!(run(), run(), "{} + store must replay deterministically", fw.name());
    }
}
