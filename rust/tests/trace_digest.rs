//! Whole-run digest audits: one FNV-1a `u64` over every emitted trace
//! event locks an entire run. Identical (scenario, bundle, seed) replays
//! must produce equal digests; different policies must not; the untraced
//! default stays byte-for-byte what it was (`trace_digest == None`, all
//! other metrics unchanged). The comparison-set bundles on the
//! memory-limited scenarios are additionally locked against
//! `tests/golden/run_digests.json` — regenerate with
//! `DALI_BLESS_DIGESTS=1 cargo test --test trace_digest`.

use dali::config::Presets;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::{replay_decode_gpus, replay_decode_store, replay_decode_traced};
use dali::hw::CostModel;
use dali::metrics::RunMetrics;
use dali::store::{PlacementCfg, TieredStore};
use dali::trace::DigestSink;
use dali::util::json::Value;
use dali::util::repo_root;
use dali::workload::trace::synthetic_locality_trace;

/// The framework bundles whose digests the golden file locks — the
/// paper's comparison set on the memory-limited scenarios.
const COMPARISON_SET: [Framework; 6] = [
    Framework::LlamaCpp,
    Framework::KTransformers,
    Framework::Fiddler,
    Framework::MoELightning,
    Framework::HybriMoE,
    Framework::Dali,
];

/// Replay `scenario` with `fw`'s bundle over the synthetic locality
/// trace. `reactive` forces the PR 1 LRU-spill placement; `traced`
/// attaches a digest sink (false reproduces the untraced default).
fn replay(scenario: &str, fw: Framework, reactive: bool, seed: u64, traced: bool) -> RunMetrics {
    let p = Presets::load_default().unwrap();
    let (model, hw) = p.scenario(scenario).unwrap();
    let c = CostModel::new(model, hw).with_quant_ratio(p.quant_ratio(scenario));
    let dims = &model.sim;
    let trace = synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 16, 48, 0x7157);
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let cfg = FrameworkCfg::paper_default(dims);
    let mut bundle = fw.bundle(dims, &c, &freq, &cfg);
    if reactive {
        bundle.placement = PlacementCfg::default();
    }
    let store = TieredStore::for_model(hw, &c, dims.layers, dims.n_routed);
    assert!(!store.is_unlimited());
    let ids: Vec<usize> = (0..8).collect();
    if traced {
        replay_decode_traced(
            &trace,
            &ids,
            40,
            &c,
            bundle,
            &freq,
            dims.n_shared,
            seed,
            Some(store),
            DigestSink::new(),
        )
        .0
    } else {
        replay_decode_store(&trace, &ids, 40, &c, bundle, &freq, dims.n_shared, seed, Some(store))
    }
}

fn digest(scenario: &str, fw: Framework, reactive: bool, seed: u64) -> u64 {
    replay(scenario, fw, reactive, seed, true)
        .trace_digest
        .expect("a digest-sink replay must surface its digest")
}

/// Like [`digest`], but replays through the N-device entry point with the
/// scenario's own `num_gpus` — the expert-parallel analogue of the golden
/// lock. At `num_gpus = 1` this is digest-identical to [`digest`] by
/// construction, so only multi-GPU scenarios earn their own keys.
fn digest_gpus(scenario: &str, fw: Framework, seed: u64) -> u64 {
    let p = Presets::load_default().unwrap();
    let (model, hw) = p.scenario(scenario).unwrap();
    let c = CostModel::new(model, hw).with_quant_ratio(p.quant_ratio(scenario));
    let dims = &model.sim;
    let trace = synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 16, 48, 0x7157);
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let cfg = FrameworkCfg::paper_default(dims);
    let bundle = fw.bundle(dims, &c, &freq, &cfg);
    let store = TieredStore::for_model(hw, &c, dims.layers, dims.n_routed);
    assert!(!store.is_unlimited());
    let ids: Vec<usize> = (0..8).collect();
    replay_decode_gpus(
        &trace,
        &ids,
        40,
        &c,
        bundle,
        &freq,
        dims.n_shared,
        seed,
        hw.num_gpus,
        None,
        Some(store),
        DigestSink::new(),
    )
    .0
    .trace_digest
    .expect("a digest-sink replay must surface its digest")
}

#[test]
fn identical_replays_produce_equal_digests() {
    for scenario in ["mixtral-sim-ram16", "mixtral-sim-ram16-q4"] {
        let a = digest(scenario, Framework::Dali, false, 11);
        let b = digest(scenario, Framework::Dali, false, 11);
        assert_eq!(a, b, "{scenario}: same (scenario, bundle, seed) must replay to one digest");
    }
}

#[test]
fn different_policies_produce_different_digests() {
    // predictive vs reactive placement schedule different event streams
    let pred = digest("mixtral-sim-ram16", Framework::Dali, false, 11);
    let lru = digest("mixtral-sim-ram16", Framework::Dali, true, 11);
    assert_ne!(pred, lru, "placement policies must be distinguishable by digest");
    // so do the on-disk formats (q4 transcodes, fp16 does not)
    let q4 = digest("mixtral-sim-ram16-q4", Framework::Dali, false, 11);
    assert_ne!(pred, q4, "on-disk formats must be distinguishable by digest");
}

#[test]
fn untraced_replay_keeps_metrics_and_reports_no_digest() {
    // The NullSink default is the zero-cost path: no digest, and every
    // other metric identical to the traced run — instrumentation observes
    // the schedule, it never perturbs it.
    let untraced = replay("mixtral-sim-ram16-q4", Framework::Dali, false, 11, false);
    assert_eq!(untraced.trace_digest, None, "tracing off means no digest");
    let mut traced = replay("mixtral-sim-ram16-q4", Framework::Dali, false, 11, true);
    assert!(traced.trace_digest.is_some());
    traced.trace_digest = None;
    assert_eq!(traced, untraced, "a sink must not change the simulated run");
}

#[test]
fn golden_digests_lock_comparison_set() {
    // Digest-locked replay audit per (scenario, bundle, seed): one u64
    // per cell replaces per-metric regression locks. Bless with
    // `DALI_BLESS_DIGESTS=1 cargo test --test trace_digest` after an
    // intentional scheduling change; unblessed entries warn (first run on
    // a fresh clone) instead of failing.
    let path = repo_root().join("rust").join("tests").join("golden").join("run_digests.json");
    let mut got: Vec<(String, u64)> = Vec::new();
    for scenario in ["mixtral-sim-ram16", "mixtral-sim-ram16-q4"] {
        for fw in COMPARISON_SET {
            let key = format!("{scenario}/{}/seed11", fw.name());
            got.push((key, digest(scenario, fw, false, 11)));
        }
    }
    // Expert-parallel cells: Dali locks the device-aware assigner's
    // schedule, HybriMoE locks the `align_devices` post-pass the
    // single-device baselines ride through. Unblessed keys warn below.
    for fw in [Framework::Dali, Framework::HybriMoE] {
        let key = format!("deepseek-v3-sim-2gpu/{}/gpus2/seed11", fw.name());
        got.push((key, digest_gpus("deepseek-v3-sim-2gpu", fw, 11)));
    }
    if std::env::var("DALI_BLESS_DIGESTS").is_ok() {
        let mut pairs: Vec<(&str, Value)> = vec![(
            "_note",
            Value::str(
                "whole-run trace digests (FNV-1a over every event); \
                 regenerate with DALI_BLESS_DIGESTS=1 cargo test --test trace_digest",
            ),
        )];
        let hex: Vec<(String, String)> =
            got.iter().map(|(k, d)| (k.clone(), format!("0x{d:016x}"))).collect();
        for (k, h) in &hex {
            pairs.push((k.as_str(), Value::str(h.clone())));
        }
        std::fs::write(&path, Value::obj(pairs).to_json() + "\n").unwrap();
        eprintln!("blessed {} digests into {}", got.len(), path.display());
        return;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing golden file {}: {e}", path.display()));
    let golden = Value::parse(&text).unwrap();
    let mut missing = Vec::new();
    for (key, d) in &got {
        match golden.opt(key) {
            Some(v) => {
                let want_hex = v.as_str().unwrap();
                let want = u64::from_str_radix(want_hex.trim_start_matches("0x"), 16).unwrap();
                assert_eq!(
                    *d, want,
                    "golden digest drift for {key}: got 0x{d:016x}, locked {want_hex} — \
                     if the scheduling change is intentional, re-bless with DALI_BLESS_DIGESTS=1"
                );
            }
            None => missing.push(key.clone()),
        }
    }
    if !missing.is_empty() {
        eprintln!(
            "warning: {} comparison-set digests not blessed yet \
             (DALI_BLESS_DIGESTS=1 cargo test --test trace_digest): {missing:?}",
            missing.len()
        );
    }
}
