//! JSON-lines trace sink tests: schema round-trip for every event
//! variant, per-lane virtual-time monotonicity, and — the acceptance
//! criterion — exact agreement between the trace's aggregates and the
//! run's own `RunMetrics` counters (lane busy integrals, prefetch and
//! promote-ahead outcomes, store traffic) on a memory-limited replay.

use std::collections::BTreeSet;

use dali::config::Presets;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::replay_decode_traced;
use dali::hw::CostModel;
use dali::metrics::RunMetrics;
use dali::store::TieredStore;
use dali::trace::{Event, JsonSink, Lane, TraceSummary};
use dali::util::json::Value;
use dali::workload::trace::synthetic_locality_trace;

/// DALI-bundle replay of the given memory-limited scenario with a JSON
/// sink over an in-memory buffer; returns the run's metrics and the
/// captured JSONL text.
fn traced_capture(scenario: &str) -> (RunMetrics, String) {
    let p = Presets::load_default().unwrap();
    let (model, hw) = p.scenario(scenario).unwrap();
    let c = CostModel::new(model, hw).with_quant_ratio(p.quant_ratio(scenario));
    let dims = &model.sim;
    let trace = synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 16, 48, 0x7157);
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let cfg = FrameworkCfg::paper_default(dims);
    let bundle = Framework::Dali.bundle(dims, &c, &freq, &cfg);
    let store = TieredStore::for_model(hw, &c, dims.layers, dims.n_routed);
    assert!(!store.is_unlimited(), "{scenario} must attach a memory-limited store");
    let ids: Vec<usize> = (0..8).collect();
    let (m, sink) = replay_decode_traced(
        &trace,
        &ids,
        40,
        &c,
        bundle,
        &freq,
        dims.n_shared,
        11,
        Some(store),
        JsonSink::new(Vec::new()),
    );
    let bytes = sink.finish().unwrap();
    (m, String::from_utf8(bytes).unwrap())
}

fn parse_events(text: &str) -> Vec<Event> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Event::from_value(&Value::parse(l).unwrap()).unwrap())
        .collect()
}

#[test]
fn every_event_variant_round_trips_through_json() {
    let examples = Event::examples();
    // the exemplar list must cover the whole taxonomy
    let names: BTreeSet<&str> = examples.iter().map(|e| e.name()).collect();
    assert_eq!(names.len(), 22, "one exemplar per variant: {names:?}");
    for ev in examples {
        let text = ev.to_value().to_json();
        let back = Event::from_value(&Value::parse(&text).unwrap())
            .unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(back, ev, "round-trip must be lossless: {text}");
    }
}

#[test]
fn from_value_rejects_unknown_events_and_lanes() {
    let bad = Value::parse(r#"{"ev": "warp_drive"}"#).unwrap();
    assert!(Event::from_value(&bad).is_err());
    let bad_lane = Value::parse(r#"{"ev": "lane", "lane": "abacus", "start": 0, "end": 1}"#).unwrap();
    assert!(Event::from_value(&bad_lane).is_err());
    assert!(Lane::from_name("nvme_read").is_ok());
    assert!(Lane::from_name("abacus").is_err());
}

#[test]
fn traced_replay_lane_intervals_are_monotone_per_lane() {
    // Every lane is a FIFO stream, so within one metrics epoch (between
    // resets) its busy intervals must be well-formed and non-overlapping
    // in emission order. A reset rebases the clock, so it clears the
    // per-lane positions.
    let (_m, text) = traced_capture("mixtral-sim-ram16-q4");
    let events = parse_events(&text);
    assert!(!events.is_empty());
    let mut last: [Option<u64>; Lane::COUNT] = [None; Lane::COUNT];
    let mut intervals = 0u64;
    for ev in &events {
        match *ev {
            Event::Reset { .. } => last = [None; Lane::COUNT],
            Event::LaneBusy { lane, start, end, .. } => {
                intervals += 1;
                assert!(end >= start, "negative interval on {}: [{start}, {end})", lane.name());
                if let Some(prev) = last[lane.idx()] {
                    assert!(
                        start >= prev,
                        "{} interval [{start}, {end}) overlaps previous end {prev}",
                        lane.name()
                    );
                }
                last[lane.idx()] = Some(end);
            }
            _ => {}
        }
    }
    assert!(intervals > 0, "a store-attached replay must emit lane intervals");
}

#[test]
fn trace_aggregates_match_run_metrics_exactly() {
    // The ISSUE acceptance: summarizing the JSONL capture reproduces the
    // run's NVMe / PCIe / transcode / compute busy times and its
    // prefetch + placement counters to exact equality — the trace is a
    // faithful serialization of the run, not an approximation of it.
    for scenario in ["mixtral-sim-ram16", "mixtral-sim-ram16-q4"] {
        let (m, text) = traced_capture(scenario);
        let s = TraceSummary::from_json_lines(&text).unwrap();
        assert_eq!(s.events, parse_events(&text).len() as u64);
        // lane busy integrals (the carry events after the warmup reset
        // re-seed in-flight NVMe/transcode work, making these exact)
        assert_eq!(s.lane_busy[Lane::NvmeRead.idx()], m.nvme_read_ns, "{scenario}: nvme read");
        assert_eq!(s.lane_busy[Lane::NvmeWrite.idx()], m.nvme_write_ns, "{scenario}: nvme write");
        assert_eq!(s.lane_busy[Lane::Transcode.idx()], m.transcode_ns, "{scenario}: transcode");
        assert_eq!(s.lane_busy[Lane::PcieDemand.idx()], m.pcie_busy_ns, "{scenario}: pcie demand");
        assert_eq!(s.lane_busy[Lane::Cpu.idx()], m.moe_cpu_busy_ns, "{scenario}: cpu");
        assert_eq!(s.lane_busy[Lane::GpuCompute.idx()], m.moe_gpu_busy_ns, "{scenario}: gpu");
        // clock + step bookkeeping
        assert_eq!(s.end_ns, m.total_ns, "{scenario}: final step end == total");
        assert_eq!(s.decode_steps, 40, "{scenario}: one step event per decode step");
        assert_eq!(s.tokens, m.tokens_out, "{scenario}: tokens");
        assert_eq!(s.resets, 1, "{scenario}: exactly the warmup reset");
        // prefetch outcomes
        assert_eq!(s.prefetch_issued, m.prefetch_issued, "{scenario}: prefetch issued");
        assert_eq!(s.prefetch_hits, m.prefetch_useful, "{scenario}: prefetch hits");
        // predictive placement outcomes
        assert_eq!(s.ahead_issued, m.store_promote_ahead, "{scenario}: ahead issued");
        assert_eq!(s.ahead_hits, m.promote_ahead_hits, "{scenario}: ahead hits");
        assert_eq!(s.ahead_misses, m.promote_ahead_misses, "{scenario}: ahead misses");
        assert_eq!(s.overlap_hidden_ns, m.nvme_overlap_hidden_ns, "{scenario}: hidden ns");
        // store traffic: every promotion is a fetch or an ahead issue
        assert_eq!(s.demand_fetches, m.tier_disk_misses, "{scenario}: demand fetches");
        assert_eq!(
            s.demand_fetches + s.spec_fetches + s.ahead_issued,
            m.store_promotions,
            "{scenario}: promotions partition into demand/spec/ahead"
        );
        assert_eq!(s.spills, m.store_spills, "{scenario}: spills");
        // the q4 scenario must actually exercise the transcode lane
        if scenario.ends_with("-q4") {
            assert!(m.transcode_ns > 0, "q4 replays must transcode");
        }
        // render smoke: the report mentions every lane and the top list
        let report = s.render(5);
        for lane in Lane::ALL {
            assert!(report.contains(lane.name()), "report must cover {}", lane.name());
        }
    }
}
