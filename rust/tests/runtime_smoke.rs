//! Integration: PJRT runtime wrappers against the python-generated
//! artifacts — shapes, bucketing/padding semantics, numerics sanity.
//! Requires `make artifacts`.

use dali::runtime::PjrtEngine;

fn engine() -> PjrtEngine {
    PjrtEngine::load("mixtral-sim").expect("run `make artifacts` first")
}


/// Shared skip probe — see `dali::runtime::live_ready`.
fn live_ready() -> bool {
    dali::runtime::live_ready()
}

#[test]
fn embed_shapes_and_padding() {
    if !live_ready() {
        return;
    }
    let rt = engine();
    let d = rt.manifest().dims.hidden;
    // t=3 pads into the t=4 bucket and slices back
    let x = rt.embed(&[1, 2, 3], &[0, 1, 2]).unwrap();
    assert_eq!(x.len(), 3 * d);
    // same tokens at a bigger batch: prefix must be identical
    let x2 = rt.embed(&[1, 2, 3, 7, 9], &[0, 1, 2, 3, 4]).unwrap();
    assert_eq!(&x[..3 * d], &x2[..3 * d]);
}

#[test]
fn gate_probs_sum_to_one_per_row() {
    if !live_ready() {
        return;
    }
    let rt = engine();
    let d = rt.manifest().dims.hidden;
    let n = rt.manifest().dims.n_routed;
    let x = rt.embed(&[5, 6], &[0, 1]).unwrap();
    let (probs, xn) = rt.gate(0, &x, 2).unwrap();
    assert_eq!(probs.len(), 2 * n);
    assert_eq!(xn.len(), 2 * d);
    for r in 0..2 {
        let s: f32 = probs[r * n..(r + 1) * n].iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "row {r} sums to {s}");
        assert!(probs[r * n..(r + 1) * n].iter().all(|&p| p >= 0.0));
    }
}

#[test]
fn expert_bucketing_consistent() {
    if !live_ready() {
        return;
    }
    let rt = engine();
    let d = rt.manifest().dims.hidden;
    let x = rt.embed(&[9, 10, 11], &[0, 1, 2]).unwrap();
    let (_, xn) = rt.gate(0, &x, 3).unwrap();
    // running 3 rows (bucket 4) must equal running each row alone (bucket 1)
    let all = rt.expert_routed(0, 2, &xn, 3).unwrap();
    for r in 0..3 {
        let one = rt.expert_routed(0, 2, &xn[r * d..(r + 1) * d], 1).unwrap();
        for c in 0..d {
            assert!(
                (all[r * d + c] - one[c]).abs() < 1e-4,
                "row {r} col {c}: {} vs {}",
                all[r * d + c],
                one[c]
            );
        }
    }
}

#[test]
fn attn_decode_updates_cache_at_pos() {
    if !live_ready() {
        return;
    }
    let rt = engine();
    let dm = rt.manifest().dims.clone();
    let d = dm.hidden;
    let row = dm.max_seq * dm.heads * dm.head_dim;
    let x = rt.embed(&[3], &[4]).unwrap();
    let kc = vec![0f32; row];
    let vc = vec![0f32; row];
    let (h, kc2, vc2) = rt.attn_decode(0, &x, &kc, &vc, &[4], 1).unwrap();
    assert_eq!(h.len(), d);
    let hw = dm.heads * dm.head_dim;
    // rows 0..4 still zero, row 4 written
    assert!(kc2[..4 * hw].iter().all(|&v| v == 0.0));
    assert!(kc2[4 * hw..5 * hw].iter().any(|&v| v != 0.0));
    assert!(vc2[4 * hw..5 * hw].iter().any(|&v| v != 0.0));
}

#[test]
fn head_logits_shape() {
    if !live_ready() {
        return;
    }
    let rt = engine();
    let v = rt.manifest().dims.vocab;
    let x = rt.embed(&[1], &[0]).unwrap();
    let logits = rt.head(&x, 1).unwrap();
    assert_eq!(logits.len(), v);
    assert!(logits.iter().all(|l| l.is_finite()));
}

#[test]
fn oversized_batch_errors_cleanly() {
    if !live_ready() {
        return;
    }
    let rt = engine();
    let toks: Vec<i32> = (0..999).map(|i| i % 100).collect();
    let pos: Vec<i32> = (0..999).collect();
    assert!(rt.embed(&toks, &pos).is_err(), "exceeds largest token bucket");
}

#[test]
fn exec_profiling_counters_advance() {
    if !live_ready() {
        return;
    }
    let rt = engine();
    let before = rt.exec_calls.get();
    let _ = rt.embed(&[1], &[0]).unwrap();
    assert_eq!(rt.exec_calls.get(), before + 1);
    assert!(rt.exec_wall_ns.get() > 0);
}
