//! Serving-layer locks: determinism and SLO-exactness of the
//! continuous-batching serving simulation, plus regression tests pinning
//! the seed-era serving bugs (token billing, queue/exec latency split,
//! unbounded request bodies, shutdown dropping pending requests).

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use dali::config::Presets;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::StepSimulator;
use dali::hw::CostModel;
use dali::metrics::percentile_ns;
use dali::serve::batcher::{BatchOutcome, BatchRunner, Batcher, BatcherCfg, GenRequest};
use dali::serve::http::read_request;
use dali::serve::{simulate_serve, ArrivalSpec, ServeSim, ServeSimCfg, SloSpec};
use dali::store::TieredStore;
use dali::trace::{DigestSink, JsonSink};
use dali::util::json::Value;
use dali::workload::trace::synthetic_locality_trace;

fn presets() -> Presets {
    Presets::load_default().unwrap()
}

// --- tentpole: digest-locked determinism ---------------------------------

#[test]
fn same_seed_serve_cells_are_bit_identical() {
    let p = presets();
    let cfg = ServeSimCfg { n_requests: 10, max_batch: 4, max_tokens: 8, ..Default::default() };
    let a = simulate_serve(&p, "mixtral-sim-ram16", Framework::Dali, &cfg, None).unwrap();
    let b = simulate_serve(&p, "mixtral-sim-ram16", Framework::Dali, &cfg, None).unwrap();
    assert!(a.run.trace_digest.is_some(), "serve cells must be digest-locked");
    assert_eq!(a, b, "same-seed serve cells must be bit-identical");
    let c = simulate_serve(
        &p,
        "mixtral-sim-ram16",
        Framework::Dali,
        &ServeSimCfg { seed: cfg.seed + 1, ..cfg },
        None,
    )
    .unwrap();
    assert_ne!(a.run.trace_digest, c.run.trace_digest, "the seed must matter");
}

// --- tentpole: SLO aggregation is exact over the event stream ------------

/// Run one serving cell with a JSONL sink, recompute every percentile
/// from the raw request-lifecycle events, and require the report to match
/// exactly — no estimation, no interpolation, no drift between what the
/// trace says happened and what the report claims.
#[test]
fn slo_percentiles_match_the_event_stream_exactly() {
    let p = presets();
    let scenario = "mixtral-sim-ram16";
    let cfg = ServeSimCfg { n_requests: 12, max_batch: 4, max_tokens: 8, ..Default::default() };
    let (model, hw) = p.scenario(scenario).unwrap();
    let dims = &model.sim;
    let cost = CostModel::for_scenario(&p, scenario).unwrap();
    let trace = synthetic_locality_trace(
        dims.layers,
        dims.n_routed,
        dims.top_k,
        16,
        cfg.max_tokens.max(16),
        cfg.seed ^ 0x7ace,
    );
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let fwcfg = FrameworkCfg::paper_default(dims);
    let bundle = Framework::Dali.bundle(dims, &cost, &freq, &fwcfg);
    let mut sim =
        StepSimulator::new(&cost, bundle, &freq, dims.layers, dims.n_routed, dims.n_shared, 7)
            .with_sink(JsonSink::new(Vec::new()));
    let store = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
    if !store.is_unlimited() {
        sim = sim.with_store(store);
    }
    let mut serve = ServeSim::new(sim, &trace, cfg.clone()).unwrap();
    serve.run();
    let (report, sink) = serve.finish_with_sink();
    let bytes = sink.finish().unwrap();
    let text = String::from_utf8(bytes).unwrap();

    // per-request lifecycle rebuilt from the raw events
    let mut arrive = vec![None; cfg.n_requests];
    let mut admit_q = vec![None; cfg.n_requests];
    let mut first = vec![None; cfg.n_requests];
    let mut finish = vec![None; cfg.n_requests];
    let mut ttft = vec![None; cfg.n_requests];
    let mut tokens = vec![0u64; cfg.n_requests];
    for line in text.lines() {
        let v = Value::parse(line).unwrap();
        let ev = v.get("ev").unwrap().as_str().unwrap().to_string();
        if !ev.starts_with("request_") {
            continue;
        }
        let req = v.get("req").unwrap().as_usize().unwrap();
        let at = v.get("at").unwrap().as_u64().unwrap();
        match ev.as_str() {
            "request_arrive" => arrive[req] = Some(at),
            "request_admit" => {
                admit_q[req] = Some(v.get("queue_ns").unwrap().as_u64().unwrap());
            }
            "request_first_token" => {
                first[req] = Some(at);
                ttft[req] = Some(v.get("ttft_ns").unwrap().as_u64().unwrap());
            }
            "request_finish" => {
                finish[req] = Some(at);
                tokens[req] = v.get("tokens").unwrap().as_u64().unwrap();
            }
            other => panic!("unexpected request event {other}"),
        }
    }
    // every request completed its full lifecycle with its full budget
    for r in 0..cfg.n_requests {
        let (a, f, fin) = (arrive[r].unwrap(), first[r].unwrap(), finish[r].unwrap());
        assert!(a <= f && f <= fin, "request {r} lifecycle out of order");
        assert_eq!(tokens[r], cfg.max_tokens as u64, "request {r} short-counted");
        assert_eq!(ttft[r].unwrap(), f - a, "request {r} ttft mismatch");
    }
    assert_eq!(report.requests, cfg.n_requests as u64);
    assert_eq!(report.tokens_out, (cfg.n_requests * cfg.max_tokens) as u64);
    assert_eq!(report.makespan_ns, finish.iter().map(|f| f.unwrap()).max().unwrap());

    // recompute every percentile from the event stream; the report must
    // agree exactly
    let mut ttfts: Vec<u64> = ttft.iter().map(|t| t.unwrap()).collect();
    let mut queues: Vec<u64> = admit_q.iter().map(|q| q.unwrap()).collect();
    let mut tpots: Vec<u64> = (0..cfg.n_requests)
        .filter(|&r| tokens[r] > 1)
        .map(|r| (finish[r].unwrap() - first[r].unwrap()) / (tokens[r] - 1))
        .collect();
    ttfts.sort_unstable();
    queues.sort_unstable();
    tpots.sort_unstable();
    assert_eq!(report.ttft_p50_ns, percentile_ns(&ttfts, 50.0));
    assert_eq!(report.ttft_p99_ns, percentile_ns(&ttfts, 99.0));
    assert_eq!(report.tpot_p50_ns, percentile_ns(&tpots, 50.0));
    assert_eq!(report.tpot_p99_ns, percentile_ns(&tpots, 99.0));
    assert_eq!(report.queue_p50_ns, percentile_ns(&queues, 50.0));
    assert_eq!(report.queue_p99_ns, percentile_ns(&queues, 99.0));
}

/// Hand-computable arrival script: at a trickle load (mean gap ~10^4
/// virtual seconds, orders of magnitude beyond any request's service
/// time) the server is idle at every arrival, so each request is
/// admitted at its exact arrival instant — queueing is identically zero
/// across the percentile range.
#[test]
fn idle_server_admits_at_arrival_with_zero_queue() {
    let p = presets();
    let cfg = ServeSimCfg {
        arrival: ArrivalSpec::default().with_rate(1e-4),
        n_requests: 6,
        max_batch: 4,
        max_tokens: 8,
        ..Default::default()
    };
    let r = simulate_serve(&p, "mixtral-sim", Framework::Dali, &cfg, None).unwrap();
    assert_eq!(r.requests, 6);
    assert_eq!(r.queue_p50_ns, 0, "idle admissions must not queue");
    assert_eq!(r.queue_p99_ns, 0, "idle admissions must not queue");
    assert!(r.ttft_p50_ns > 0, "prefill + first decode step still take time");
}

// --- tentpole: SLO-guarded overload protection ---------------------------

/// The bursty overload cell the guarded-vs-unguarded comparison runs on:
/// a near-simultaneous burst of 32 requests into 4 slots.
fn overload_cfg() -> ServeSimCfg {
    ServeSimCfg {
        arrival: ArrivalSpec::parse_spec("kind=bursty,rate=512,burst=8").unwrap(),
        n_requests: 32,
        max_batch: 4,
        max_tokens: 8,
        ..Default::default()
    }
}

#[test]
fn unlimited_slo_is_bit_identical_to_the_unguarded_simulator() {
    let p = presets();
    let base =
        simulate_serve(&p, "mixtral-sim-ram16", Framework::Dali, &overload_cfg(), None).unwrap();
    let unlimited = simulate_serve(
        &p,
        "mixtral-sim-ram16",
        Framework::Dali,
        &ServeSimCfg { slo: SloSpec::named("unlimited").unwrap(), ..overload_cfg() },
        None,
    )
    .unwrap();
    assert_eq!(base, unlimited, "the default SLO spec must change nothing, bit for bit");
}

/// The PR's acceptance gate: on a bursty overload cell, the guarded
/// pipeline must *strictly* beat the unguarded one on SLO attainment AND
/// on p99 TTFT of accepted requests — while actually shedding load.
///
/// The winning budget is self-calibrating rather than hard-coded: the
/// baseline run's own TTFT distribution seeds a small grid of candidate
/// policies (plus one completion-budget candidate that exercises
/// eviction), and at least one must win on both axes. This keeps the
/// lock meaningful across cost-model retunes — the comparison is always
/// "this workload against budgets this workload can partially meet".
#[test]
fn guarded_overload_strictly_beats_unguarded_on_attainment_and_tail() {
    let p = presets();
    let scenario = "mixtral-sim-ram16";
    let base_cfg = overload_cfg();
    // manual cell (same construction as simulate_serve) so the raw
    // per-request stats are readable for calibration
    let (model, hw) = p.scenario(scenario).unwrap();
    let dims = &model.sim;
    let cost = CostModel::for_scenario(&p, scenario).unwrap();
    let trace = synthetic_locality_trace(
        dims.layers,
        dims.n_routed,
        dims.top_k,
        16,
        base_cfg.max_tokens.max(16),
        base_cfg.seed ^ 0x7ace,
    );
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let fwcfg = FrameworkCfg::paper_default(dims);
    let bundle = Framework::Dali.bundle(dims, &cost, &freq, &fwcfg);
    let mut sim =
        StepSimulator::new(&cost, bundle, &freq, dims.layers, dims.n_routed, dims.n_shared, 7)
            .with_sink(DigestSink::new());
    let store = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
    if !store.is_unlimited() {
        sim = sim.with_store(store);
    }
    let mut serve = ServeSim::new(sim, &trace, base_cfg.clone()).unwrap();
    serve.run();
    let mut ttfts: Vec<u64> = serve
        .stats()
        .iter()
        .map(|s| s.first_token_ns.saturating_sub(s.arrival_ns))
        .collect();
    ttfts.sort_unstable();
    let base = serve.finish();
    assert_eq!(base.finished, base.requests, "unguarded cell finishes everything");
    assert!(base.ttft_p99_ns > base.ttft_p50_ns, "cell must actually be overloaded");

    // candidate budgets from the baseline's own TTFT quantiles, plus one
    // completion-only budget that forces the eviction path
    let pick = |q: f64| ttfts[((ttfts.len() - 1) as f64 * q) as usize];
    let mut candidates: Vec<SloSpec> = [0.25, 0.5, 0.75]
        .iter()
        .map(|&q| SloSpec {
            ttft_ms: pick(q) as f64 / 1e6,
            jitter: 0.0,
            queue_cap: 8,
            hi_queue: 6,
            lo_queue: 1,
            ..SloSpec::default()
        })
        .collect();
    candidates.push(SloSpec {
        total_ms: (base.makespan_ns / 2) as f64 / 1e6,
        jitter: 0.0,
        ..SloSpec::default()
    });

    let mut won = false;
    let mut seen = Vec::new();
    for spec in candidates {
        // observe mode: identical traffic and schedule, deadlines scored
        // but never enforced — the fair unguarded yardstick
        let observe = simulate_serve(
            &p,
            scenario,
            Framework::Dali,
            &ServeSimCfg { slo: SloSpec { enforce: false, ..spec }, ..base_cfg.clone() },
            None,
        )
        .unwrap();
        assert_eq!(
            observe.run.trace_digest, base.run.trace_digest,
            "observe mode must be digest-transparent for every candidate"
        );
        let guarded = simulate_serve(
            &p,
            scenario,
            Framework::Dali,
            &ServeSimCfg { slo: spec, ..base_cfg.clone() },
            None,
        )
        .unwrap();
        assert_eq!(
            guarded.finished + guarded.rejected + guarded.evicted,
            guarded.requests,
            "guarded cell must resolve every request exactly once"
        );
        let shed = guarded.rejected + guarded.evicted;
        seen.push((
            spec,
            observe.slo_attainment(),
            guarded.slo_attainment(),
            base.ttft_p99_ns,
            guarded.ttft_p99_ns,
            shed,
        ));
        if guarded.slo_attainment() > observe.slo_attainment()
            && guarded.ttft_p99_ns < base.ttft_p99_ns
            && shed > 0
        {
            won = true;
        }
    }
    assert!(
        won,
        "no candidate SLO policy strictly beat unguarded on both attainment and \
         accepted-TTFT p99 while shedding load; cells: {seen:#?}"
    );
}

// --- bugfix: tokens_out billed actual generation, sim covers it ----------

/// Runner that stops every odd request one token short of its budget.
struct ShortStopRunner;

impl BatchRunner for ShortStopRunner {
    fn run(&mut self, prompts: &[Vec<i32>], max_tokens: usize) -> Result<BatchOutcome, String> {
        Ok(BatchOutcome {
            generated: prompts
                .iter()
                .enumerate()
                .map(|(i, _)| vec![7; max_tokens - (i % 2)])
                .collect(),
            sim_ms: 1.0,
            sim_tokens_per_s: 100.0,
        })
    }
}

fn short_stop_batcher(max_batch: usize) -> std::sync::Arc<Batcher> {
    let cfg = BatcherCfg {
        max_batch,
        max_wait: Duration::from_secs(10),
        ..Default::default()
    };
    Batcher::start_with(cfg, || Ok(Box::new(ShortStopRunner) as Box<dyn BatchRunner>)).unwrap()
}

#[test]
fn tokens_out_bills_generated_tokens_not_requested_budget() {
    let b = short_stop_batcher(2);
    let rx0 = b.submit(GenRequest { prompt: vec![1, 2], max_tokens: 6 });
    let rx1 = b.submit(GenRequest { prompt: vec![3, 4], max_tokens: 6 });
    let r0 = rx0.recv().unwrap().unwrap();
    let r1 = rx1.recv().unwrap().unwrap();
    assert_eq!(r0.tokens.len() + r1.tokens.len(), 11, "6 + 5 actual tokens");
    let m = b.metrics.lock().unwrap().clone();
    assert_eq!(m.tokens_out, 11, "seed bug billed steps * batch = 12");
    b.shutdown();
}

// --- bugfix: queue vs exec latency split ---------------------------------

#[test]
fn queue_and_exec_latency_split_is_consistent() {
    let b = short_stop_batcher(1);
    let rx = b.submit(GenRequest { prompt: vec![1], max_tokens: 2 });
    let r = rx.recv().unwrap().unwrap();
    assert!(
        (r.wall_ms - (r.queue_ms + r.exec_ms)).abs() < 1e-9,
        "wall must be exactly queue + exec"
    );
    let m = b.metrics.lock().unwrap().clone();
    assert!((m.queue_ms_sum - r.queue_ms).abs() < 1e-9, "metrics use the same queue component");
    assert!((m.exec_ms_sum - r.exec_ms).abs() < 1e-9, "metrics use the same exec component");
    b.shutdown();
}

// --- bugfix: request body size is bounded --------------------------------

fn parse_raw(raw: &[u8]) -> Result<dali::serve::http::Request, dali::serve::http::HttpError> {
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let raw = raw.to_vec();
    let writer = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&raw).unwrap();
    });
    let (mut stream, _) = listener.accept().unwrap();
    let r = read_request(&mut stream);
    writer.join().unwrap();
    r
}

#[test]
fn oversized_body_is_rejected_with_413_not_allocated() {
    // the seed code did `vec![0u8; content_length]` straight from the
    // header — this request would have allocated ~93 GB
    let e = parse_raw(b"POST /generate HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n")
        .unwrap_err();
    assert_eq!(e.status, 413, "{e}");
    // a sane request still parses
    let r = parse_raw(b"POST /generate HTTP/1.1\r\nContent-Length: 2\r\n\r\nok").unwrap();
    assert_eq!(r.body, b"ok");
}

// --- bugfix: shutdown joins the worker and drains the queue --------------

#[test]
fn shutdown_drains_pending_requests_with_explicit_errors() {
    // out-of-reach batch threshold and wait: nothing ever dispatches
    let cfg = BatcherCfg {
        max_batch: 8,
        max_wait: Duration::from_secs(3600),
        ..Default::default()
    };
    let b = Batcher::start_with(cfg, || Ok(Box::new(ShortStopRunner) as Box<dyn BatchRunner>))
        .unwrap();
    let rx0 = b.submit(GenRequest { prompt: vec![1], max_tokens: 4 });
    let rx1 = b.submit(GenRequest { prompt: vec![1, 2], max_tokens: 4 });
    // shutdown returns only after the worker has been joined; the seed
    // code flipped a flag and left pending requests hanging forever
    b.shutdown();
    for rx in [rx0, rx1] {
        let err = rx.recv().expect("drained with an error, not dropped").unwrap_err();
        assert!(err.contains("shutting down"), "got: {err}");
    }
    // late submissions fail immediately instead of queueing into nowhere
    let rx = b.submit(GenRequest { prompt: vec![1], max_tokens: 4 });
    assert!(rx.recv().unwrap().is_err());
    b.shutdown(); // idempotent
}
