//! Property + integration tests for the tiered expert store
//! (`dali::store`): residency conservation, host slot-capacity invariants
//! under random promote/demote sequences, GPU-memory-model consistency,
//! the unlimited-RAM two-tier regression, and the memory-limited
//! end-to-end run through `simrun` (ISSUE acceptance criteria).

use dali::config::Presets;
use dali::coordinator::assignment::GreedyAssigner;
use dali::coordinator::cache::WorkloadAwareCache;
use dali::coordinator::prefetch::{NoPrefetcher, ResidualPrefetcher};
use dali::coordinator::simrun::{replay_decode_gpus, Phase, PolicyBundle, StepSimulator};
use dali::hw::GpuMemModel;
use dali::metrics::RunMetrics;
use dali::store::{StoreCfg, Tier, TieredStore};
use dali::trace::DigestSink;
use dali::util::DetRng;
use dali::workload::trace::{synthetic_locality_trace, BatchStep, LayerStepData};
use dali::CostModel;

fn cost(model: &str, hw: &str) -> CostModel {
    let p = Presets::load_default().unwrap();
    CostModel::new(p.model(model).unwrap(), p.hw(hw).unwrap())
}

/// Run `f` over `n` seeded cases, reporting the failing seed.
fn for_seeds(n: u64, f: impl Fn(u64)) {
    for seed in 0..n {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if result.is_err() {
            panic!("property failed at seed {seed}");
        }
    }
}

#[test]
fn prop_residency_conserved_under_random_ops() {
    // Every expert is in exactly one tier, host accounting never drifts,
    // and the slot capacity is never exceeded — under arbitrary interleaved
    // promote / admit / demote / touch sequences.
    let c = cost("mixtral-sim", "local-pc-ram16");
    for_seeds(120, |seed| {
        let mut rng = DetRng::new(seed);
        let layers = 1 + rng.usize_below(6);
        let n = 2 + rng.usize_below(14);
        let total = layers * n;
        let slots = 1 + rng.usize_below(total);
        let mut st = TieredStore::new(
            layers,
            n,
            StoreCfg { host_slots: slots, spill_writeback: rng.chance(0.5) },
        );
        let mut now = 0u64;
        for _ in 0..200 {
            let l = rng.usize_below(layers);
            let e = rng.usize_below(n);
            match rng.usize_below(4) {
                0 => {
                    now += 1;
                    st.ensure_host(l, e, now, &c);
                }
                1 => {
                    // admission models the cache loading a host-resident
                    // expert; promote first as the simulator does
                    now += 1;
                    st.ensure_host(l, e, now, &c);
                    st.admit_to_gpu(l, e);
                }
                2 => st.demote_gpu(l, e),
                _ => st.touch(l, e),
            }
            st.check_invariants().unwrap();
            let (g, h, d) = st.counts();
            assert_eq!(g + h + d, total, "residency must be conserved");
            assert!(g + h <= st.host_slots(), "slot capacity violated");
        }
        // ensure_host is what promoted everything: spills must have been
        // forced whenever promotions exceeded the (possibly floor-raised)
        // slot budget — host_used = initial + promotions - spills ≤ slots
        if st.promotions as usize > st.host_slots() {
            assert!(st.spills > 0, "over-budget promotions require spills");
        }
    });
}

#[test]
fn prop_nvme_streams_account_all_promotions() {
    // Each disk→host promotion charges exactly one expert's bytes on the
    // read stream; write traffic appears iff write-back spilling is on.
    let c = cost("deepseek-sim", "local-pc-ram16");
    for_seeds(60, |seed| {
        let mut rng = DetRng::new(seed);
        let writeback = rng.chance(0.5);
        let mut st =
            TieredStore::new(2, 8, StoreCfg { host_slots: 3, spill_writeback: writeback });
        for i in 0..50 {
            st.ensure_host(rng.usize_below(2), rng.usize_below(8), i, &c);
        }
        let expert_bytes = c.expert_bytes() as u64;
        assert_eq!(st.xfer.read_bytes, st.promotions * expert_bytes);
        assert_eq!(st.xfer.reads, st.promotions);
        if writeback {
            assert_eq!(st.xfer.write_bytes, st.spills * expert_bytes);
        } else {
            assert_eq!(st.xfer.write_bytes, 0);
        }
        st.check_invariants().unwrap();
    });
}

#[test]
fn prop_quantized_disk_accounting_conserves() {
    // Quantized-format invariants: on-disk bytes never exceed the fp16
    // host footprint, every promotion moves exactly the on-disk bytes and
    // chains exactly one transcode, bytes-saved accounting matches
    // (promotions + write-backs) × (fp16 − disk) bytes, and demand
    // arrivals land at transcode completion — across random promote/spill
    // cycles and ratios.
    let base = cost("mixtral-sim", "local-pc-ram16");
    for_seeds(60, |seed| {
        let mut rng = DetRng::new(seed ^ 0x9a4d);
        let ratio = 0.15 + 0.1 * (seed % 9) as f64; // 0.15 ..= 0.95
        let c = base.clone().with_quant_ratio(ratio);
        let writeback = rng.chance(0.5);
        let mut st =
            TieredStore::new(2, 8, StoreCfg { host_slots: 3, spill_writeback: writeback });
        for i in 0..60 {
            st.ensure_host(rng.usize_below(2), rng.usize_below(8), i, &c);
        }
        let disk_bytes = c.disk_expert_bytes() as u64;
        let fp_bytes = c.expert_bytes() as u64;
        assert!(disk_bytes <= fp_bytes, "on-disk format never exceeds fp16");
        assert_eq!(st.xfer.read_bytes, st.promotions * disk_bytes);
        assert_eq!(st.xfer.read_busy, st.promotions * c.nvme_read_time());
        // one transcode per promotion (dequantize) — plus one per
        // write-back spill (re-quantize) — iff the format is quantized
        let transcodes = if c.transcode_time() == 0 {
            0
        } else if writeback {
            st.promotions + st.spills
        } else {
            st.promotions
        };
        assert_eq!(st.xfer.transcodes, transcodes);
        assert_eq!(st.xfer.transcode_busy, transcodes * c.transcode_time());
        let mut saved = st.promotions * (fp_bytes - disk_bytes);
        if writeback {
            assert_eq!(st.xfer.write_bytes, st.spills * disk_bytes);
            saved += st.spills * (fp_bytes - disk_bytes);
        } else {
            assert_eq!(st.xfer.write_bytes, 0);
        }
        assert_eq!(st.bytes_saved, saved);
        st.check_invariants().unwrap();
    });
}

#[test]
fn quantized_demand_arrival_is_transcode_completion() {
    // A single demand promotion on an idle store: the returned host
    // arrival is read + transcode — the transcode appears in the demand
    // arrival, never on any GPU stream (the store owns no GPU lanes).
    let c = cost("mixtral-sim", "local-pc-ram16").with_quant_ratio(0.28);
    let mut st = TieredStore::new(1, 8, StoreCfg { host_slots: 4, ..Default::default() });
    let arr = st.ensure_host(0, 6, 0, &c);
    assert!(c.transcode_time() > 0);
    assert_eq!(arr, c.nvme_read_time() + c.transcode_time());
    assert_eq!(st.demand_read_ns, c.nvme_read_time(), "demand charge is the read alone");
    st.check_invariants().unwrap();
}

fn mk_step(layers: usize, n: usize, w: &[u32]) -> BatchStep {
    assert_eq!(w.len(), n);
    BatchStep {
        tokens: (w.iter().sum::<u32>() as usize / 2).max(1),
        layers: (0..layers)
            .map(|_| LayerStepData {
                workloads: w.to_vec(),
                gate_scores: w.iter().map(|&x| x as f32 * 0.4).collect(),
                pred_raw: w.to_vec(),
                pred_res: w.to_vec(),
            })
            .collect(),
    }
}

fn bundle(layers: usize, n: usize, cache_size: usize, prefetch: bool) -> PolicyBundle {
    PolicyBundle {
        assigner: Box::new(GreedyAssigner::new()),
        prefetcher: if prefetch {
            Box::new(ResidualPrefetcher)
        } else {
            Box::new(NoPrefetcher)
        },
        cache: Box::new(WorkloadAwareCache::new(layers, n, cache_size, 4, 1, 9)),
        prefetch_size: usize::from(prefetch),
        cpu_eff: 1.0,
        layer_overhead_ns: 0,
        gpu_free_slots: n,
        solve_cost: Default::default(),
        placement: Default::default(),
    }
}

fn run_sim(
    c: &CostModel,
    layers: usize,
    n: usize,
    store: Option<TieredStore>,
    steps: usize,
    workloads: &[u32],
) -> (RunMetrics, Option<(usize, usize, usize)>, Option<usize>) {
    let freq = vec![vec![0.0; n]; layers];
    let mut sim = StepSimulator::new(c, bundle(layers, n, 2, true), &freq, layers, n, 0, 7);
    if let Some(st) = store {
        sim = sim.with_store(st);
    }
    for _ in 0..steps {
        sim.run_step(&mk_step(layers, n, workloads), 16, Phase::Decode);
    }
    let counts = sim.store().map(|s| s.counts());
    let gpu_layer0 = sim.store().map(|s| s.gpu_count_layer(0));
    if let Some(st) = sim.store() {
        st.check_invariants().unwrap();
    }
    (sim.finish(), counts, gpu_layer0)
}

#[test]
fn unlimited_store_regression_matches_two_tier_exactly() {
    // ISSUE acceptance: with an unlimited host-RAM budget the store must
    // reproduce the seed's two-tier virtual-time results exactly.
    for model in ["mixtral-sim", "deepseek-sim", "qwen-sim"] {
        let c = cost(model, "local-pc");
        let n = if model == "mixtral-sim" { 8 } else { 16 };
        let w: Vec<u32> = (0..n).map(|e| ((e * 5) % 9) as u32).collect();
        let (two_tier, _, _) = run_sim(&c, 4, n, None, 24, &w);
        let (mut tiered, counts, _) =
            run_sim(&c, 4, n, Some(TieredStore::unlimited(4, n)), 24, &w);
        assert_eq!(tiered.nvme_read_bytes, 0);
        assert_eq!(tiered.nvme_write_bytes, 0);
        assert_eq!(tiered.store_promotions, 0);
        assert_eq!(tiered.tier_disk_misses, 0);
        let (_, _, d) = counts.unwrap();
        assert_eq!(d, 0, "nothing may spill to disk with unlimited RAM");
        // free GPU↔host bookkeeping is the only permitted metrics delta
        tiered.store_gpu_demotions = two_tier.store_gpu_demotions;
        assert_eq!(tiered, two_tier, "{model}: tiered store must be timing-transparent");
    }
}

#[test]
fn memory_limited_preset_end_to_end_reports_tier_metrics() {
    // ISSUE acceptance: a memory-limited preset (host RAM < total expert
    // bytes) runs end-to-end through simrun and reports per-tier hit/miss
    // counters and NVMe transfer time in its metrics.
    let p = Presets::load_default().unwrap();
    let (model, hw) = p.scenario("mixtral-sim-ram16").unwrap();
    assert!(hw.is_memory_limited(&model.paper));
    let c = CostModel::new(model, hw);
    let layers = model.sim.layers;
    let n = model.sim.n_routed;
    let store = TieredStore::for_model(hw, &c, layers, n);
    assert!(!store.is_unlimited());
    let w: Vec<u32> = (0..n).map(|e| 2 + ((e * 3) % 7) as u32).collect();
    let (m, counts, _) = run_sim(&c, layers, n, Some(store), 24, &w);
    // per-tier counters present and coherent
    assert!(m.tier_disk_misses > 0, "disk tier must be exercised");
    assert!(m.tier_gpu_hits > 0 || m.tier_host_hits > 0);
    assert_eq!(m.tier_lookups(), m.tier_gpu_hits + m.tier_host_hits + m.tier_disk_misses);
    assert!(m.disk_miss_rate() > 0.0 && m.disk_miss_rate() <= 1.0);
    // NVMe transfer time reported
    assert!(m.nvme_read_ns > 0 && m.nvme_read_bytes > 0);
    assert!(m.store_promotions > 0);
    assert!(m.nvme_time_share() > 0.0);
    // something is still on disk at steady state (16 GB < 90 GB)
    let (_, _, d) = counts.unwrap();
    assert!(d > 0);
    // and the RAM limit costs real virtual time vs the unlimited run
    let (fast, _, _) = run_sim(&c, layers, n, Some(TieredStore::unlimited(layers, n)), 24, &w);
    assert!(m.total_ns > fast.total_ns);
    assert!(m.tokens_per_s() < fast.tokens_per_s());
}

#[test]
fn store_accounting_consistent_with_gpu_mem_model() {
    // The store's GPU-primary census must stay within what GpuMemModel
    // budgets for the cache: per-layer GPU-resident experts never exceed
    // the cache capacity, and the paper-scale byte footprint of the
    // store's GPU tier never exceeds the modelled cache bytes.
    let p = Presets::load_default().unwrap();
    let model = p.model("mixtral-sim").unwrap();
    let c = CostModel::new(model, p.hw("local-pc-ram16").unwrap());
    let mem = GpuMemModel::new(&model.paper);
    let layers = 4;
    let n = 8;
    let cache_size = 2;
    let freq = vec![vec![0.0; n]; layers];
    let mut sim = StepSimulator::new(&c, bundle(layers, n, cache_size, false), &freq, layers, n, 0, 3)
        .with_store(TieredStore::new(
        layers,
        n,
        StoreCfg { host_slots: 12, ..Default::default() },
    ));
    let w: Vec<u32> = (0..n).map(|e| ((e * 7) % 11) as u32).collect();
    for _ in 0..24 {
        sim.run_step(&mk_step(layers, n, &w), 8, Phase::Decode);
    }
    let st = sim.store().unwrap();
    st.check_invariants().unwrap();
    let (gpu_total, _, _) = st.counts();
    let mut per_layer_max = 0;
    for l in 0..layers {
        per_layer_max = per_layer_max.max(st.gpu_count_layer(l));
        assert!(
            st.gpu_count_layer(l) <= cache_size,
            "layer {l}: {} GPU-primary experts exceed cache capacity {cache_size}",
            st.gpu_count_layer(l)
        );
    }
    // paper-scale bytes: store census vs memory-model budget. The store
    // tracks the sim grid; scale each sim expert to its paper footprint
    // (paper layers / sim layers experts per sim slot).
    let paper_per_sim = (model.paper.layers as f64 / layers as f64).ceil();
    let store_gpu_bytes = gpu_total as f64 * paper_per_sim * c.expert_bytes();
    assert!(
        store_gpu_bytes <= mem.cache_bytes(per_layer_max) * 1.001,
        "store GPU bytes {store_gpu_bytes:.2e} exceed memory model {:.2e}",
        mem.cache_bytes(per_layer_max)
    );
}

#[test]
fn prop_multi_device_residency_and_p2p_accounting() {
    // Expert-parallel satellite: under random multi-device op sequences
    // (promote / home admit / explicit-device admit / P2P migrate /
    // demote), residency stays single-copy, the per-device counts always
    // partition the GPU tier, and the P2P fabric ledger charges exactly
    // one expert of fp16 bytes and one `p2p_time()` per effective move.
    let c = cost("mixtral-sim", "local-pc-2gpu");
    for_seeds(80, |seed| {
        let mut rng = DetRng::new(seed ^ 0x2d0c);
        let layers = 1 + rng.usize_below(4);
        let n = 4 + rng.usize_below(12);
        let nd = 2 + rng.usize_below(3); // 2..=4 device tiers
        let total = layers * n;
        let slots = 2 + rng.usize_below(total);
        let mut st = TieredStore::new(layers, n, StoreCfg { host_slots: slots, ..Default::default() });
        st.set_n_devices(nd);
        assert_eq!(st.n_devices(), nd);
        let mut now = 0u64;
        let mut moves = 0u64;
        for _ in 0..200 {
            let l = rng.usize_below(layers);
            let e = rng.usize_below(n);
            match rng.usize_below(5) {
                0 => {
                    now += 1;
                    st.ensure_host(l, e, now, &c);
                }
                1 => {
                    // home-device admission (the cache-window path)
                    now += 1;
                    st.ensure_host(l, e, now, &c);
                    st.admit_to_gpu(l, e);
                    assert_eq!(st.tier(l, e), Tier::Gpu(st.home_device(e)));
                }
                2 => {
                    // demand admission onto the executing device
                    now += 1;
                    let d = rng.usize_below(nd) as u8;
                    st.ensure_host(l, e, now, &c);
                    st.admit_to_gpu_dev(l, e, d);
                    assert_eq!(st.tier(l, e), Tier::Gpu(d));
                }
                3 => {
                    if let Tier::Gpu(from) = st.tier(l, e) {
                        now += 1;
                        let to = rng.usize_below(nd) as u8;
                        let end = st.migrate_gpu_dev(l, e, to, now, &c);
                        if from == to {
                            assert_eq!(end, now, "same-device move must be free");
                        } else {
                            moves += 1;
                            assert!(end >= now + c.p2p_time());
                        }
                        assert_eq!(st.tier(l, e), Tier::Gpu(to));
                    }
                }
                _ => st.demote_gpu(l, e),
            }
            st.check_invariants().unwrap();
            let (g, h, d) = st.counts();
            assert_eq!(g + h + d, total, "residency must be conserved");
            let dev_sum: usize = (0..nd).map(|dd| st.gpu_used_dev(dd)).sum();
            assert_eq!(dev_sum, g, "per-device counts must partition the GPU tier");
        }
        // the fabric ledger: one copy, one expert of fp16 bytes, one
        // p2p_time of lane busy per effective migration — never more
        assert_eq!(st.p2p_migrations, moves);
        assert_eq!(st.xfer.p2p_copies, moves);
        assert_eq!(st.xfer.p2p_bytes, moves * c.expert_bytes() as u64);
        assert_eq!(st.xfer.p2p_busy, moves * c.p2p_time());
    });
}

#[test]
fn deepseek_v3_two_gpu_strictly_beats_one_gpu_on_decode_latency() {
    // ISSUE acceptance (regression-locked): the deepseek-v3-sim-2gpu rig
    // must strictly beat the same rig with one device on modeled decode
    // latency. The workload gives every expert a heavy token load, so the
    // per-device PCIe upload + compute lanes carry the critical path and
    // the second device adds real service capacity for the greedy
    // assigner to balance onto.
    let p = Presets::load_default().unwrap();
    let (model, hw) = p.scenario("deepseek-v3-sim-2gpu").unwrap();
    assert_eq!(hw.num_gpus, 2, "scenario must pin a 2-GPU hardware preset");
    let c = CostModel::new(model, hw).with_quant_ratio(p.quant_ratio("deepseek-v3-sim-2gpu"));
    let layers = model.sim.layers;
    let n = model.sim.n_routed;
    let w: Vec<u32> = vec![16; n];
    let freq = vec![vec![0.0; n]; layers];
    let run = |gpus: usize| {
        let mut sim = StepSimulator::new(&c, bundle(layers, n, 2, false), &freq, layers, n, 0, 7)
            .with_gpus(gpus);
        for _ in 0..12 {
            sim.run_step(&mk_step(layers, n, &w), 16, Phase::Decode);
        }
        sim.finish()
    };
    let one = run(1);
    let two = run(2);
    assert_eq!(one.tokens_out, two.tokens_out, "device count must not change the output");
    assert_eq!(one.dev_compute_busy_ns[1], 0, "one device tier must never touch device 1");
    assert!(
        two.dev_compute_busy_ns[0] > 0 && two.dev_compute_busy_ns[1] > 0,
        "both devices must execute experts"
    );
    assert!(
        two.total_ns < one.total_ns,
        "2-GPU decode must be strictly faster: {} >= {}",
        two.total_ns,
        one.total_ns
    );
}

#[test]
fn deepseek_v3_memory_limited_multi_gpu_replay_is_coherent() {
    // The full memory-limited scenario end-to-end on 2 device tiers: the
    // replay is bit-deterministic, both devices do compute, the per-device
    // counters partition the aggregate, and the P2P ledger stays coherent
    // (every fabric byte belongs to a whole-expert copy; re-homes are a
    // subset of copies).
    let p = Presets::load_default().unwrap();
    let scenario = "deepseek-v3-sim-2gpu";
    let (model, hw) = p.scenario(scenario).unwrap();
    let c = CostModel::for_scenario(&p, scenario).unwrap();
    let dims = &model.sim;
    let t = synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 8, 40, 0xd5ee);
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let ids: Vec<usize> = (0..8).collect();
    let run = || {
        let mut bundle = bundle(dims.layers, dims.n_routed, dims.n_routed / 2, true);
        bundle.placement = dali::store::PlacementCfg::predictive(1);
        let store = TieredStore::for_model(hw, &c, dims.layers, dims.n_routed);
        assert!(!store.is_unlimited(), "{scenario} must be memory-limited");
        replay_decode_gpus(
            &t,
            &ids,
            24,
            &c,
            bundle,
            &freq,
            dims.n_shared,
            7,
            hw.num_gpus,
            None,
            Some(store),
            DigestSink::new(),
        )
        .0
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "{scenario}: 2-GPU store replay must be bit-identical, digest included");
    assert!(a.trace_digest.is_some());
    assert!(a.tokens_out > 0 && a.tier_disk_misses > 0, "the NVMe tier must be exercised");
    assert!(
        a.dev_compute_busy_ns[0] > 0 && a.dev_compute_busy_ns[1] > 0,
        "expert-parallel sharding must engage both devices"
    );
    assert_eq!(
        a.dev_cache_hits.iter().sum::<u64>(),
        a.cache_hits,
        "per-device cache hits must partition the aggregate counter"
    );
    if a.p2p_copies > 0 {
        assert_eq!(a.p2p_bytes, a.p2p_copies * c.expert_bytes() as u64);
        assert!(a.p2p_busy_ns > 0);
    }
    assert!(a.p2p_migrations <= a.p2p_copies, "re-homes are a subset of fabric copies");
}

#[test]
fn tier_aware_assignment_prefers_host_experts() {
    // Two identical workloads, one host- one disk-resident: the greedy
    // assigner must see the NVMe fetch in the disk expert's cost on both
    // devices (AssignCtx::t_cpu / t_gpu tier-awareness).
    use dali::coordinator::assignment::{AssignCtx, Assigner};
    let c = cost("mixtral-sim", "local-pc-ram16");
    let workloads = vec![6u32, 6];
    let resident = vec![false, false];
    let tiers = vec![Tier::Host, Tier::Disk];
    let ctx = AssignCtx {
        workloads: &workloads,
        resident: &resident,
        tiers: Some(&tiers),
        host_wait: None,
        cost: &c,
        gpu_free_slots: 2,
        layer: 0,
        layers: 4,
        devices: None,
    };
    assert!(ctx.t_cpu(1) > ctx.t_cpu(0));
    assert!(ctx.t_gpu(1) > ctx.t_gpu(0));
    let a = GreedyAssigner::new().assign(&ctx);
    assert!(a.satisfies_constraints(&ctx));
}
