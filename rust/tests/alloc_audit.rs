//! Steady-state allocation audit for the simulator hot path.
//!
//! This file is its own integration-test binary on purpose: it installs a
//! counting global allocator, and being the only test here means no other
//! test thread can pollute the counters between the two snapshots.
//!
//! The ISSUE acceptance criterion: `run_step` performs **zero** heap
//! allocation in steady state — the `StepScratch` buffers, the flat
//! prefetch-arrival table, the `*_into` policy APIs, and the reused
//! `BatchStep` absorb every per-step temporary after warm-up.

use dali::util::alloc_counter::{alloc_calls, CountingAlloc};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use dali::config::Presets;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::{Phase, StepSimulator};
use dali::fault::FaultPlan;
use dali::hw::CostModel;
use dali::serve::{ArrivalSpec, ServeSim, ServeSimCfg, SloSpec};
use dali::store::TieredStore;
use dali::trace::DigestSink;
use dali::workload::trace::{synthetic_locality_trace, BatchStep};

#[test]
fn run_step_steady_state_is_allocation_free() {
    // DALI (greedy + residual prefetch + workload-aware cache) and
    // HybriMoE (static threshold + feature prefetch + score cache) — the
    // two bundles the throughput benches measure head-to-head — plus the
    // memory-limited `mixtral-sim-ram16` scenario, which exercises the
    // tiered store's predictive-placement hot path (promote-ahead, score
    // demotion, host-arrival tracking) and must be just as allocation-free
    // as the two-tier bundles. `mixtral-sim-ram16-q4` repeats that with
    // the quantized on-disk format: smaller NVMe reads chained into the
    // CPU transcode lane, equally allocation-free.
    let presets = Presets::load_default().unwrap();
    for (scenario, fw) in [
        ("mixtral-sim", Framework::Dali),
        ("deepseek-sim", Framework::Dali),
        ("mixtral-sim", Framework::HybriMoE),
        ("mixtral-sim-ram16", Framework::Dali),
        ("mixtral-sim-ram16-q4", Framework::Dali),
    ] {
        let (model, hw) = presets.scenario(scenario).unwrap();
        let dims = &model.sim;
        let cost = CostModel::for_scenario(&presets, scenario).unwrap();
        let trace =
            synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 16, 96, 0xa11c);
        let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
        let cfg = FrameworkCfg::paper_default(dims);
        let bundle = fw.bundle(dims, &cost, &freq, &cfg);
        let ids: Vec<usize> = (0..8).collect();
        let mut sim = StepSimulator::new(
            &cost,
            bundle,
            &freq,
            dims.layers,
            dims.n_routed,
            dims.n_shared,
            7,
        );
        let store = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
        let memory_limited = !store.is_unlimited();
        if memory_limited {
            sim = sim.with_store(store);
        }
        let mut step = BatchStep::default();
        trace.compose_prefill_into(&ids, &mut step);
        sim.run_step(&step, 8, Phase::Prefill);
        sim.reset_metrics();
        // generous warm-up: several cache windows, prefetch issue/arrival
        // cycles, and every policy branch the workload can hit
        let warmup = 32;
        for s in 0..warmup {
            trace.compose_decode_into(&ids, s, &mut step);
            sim.run_step(&step, 16 + s, Phase::Decode);
        }
        let before = alloc_calls();
        for s in warmup..trace.min_steps() {
            trace.compose_decode_into(&ids, s, &mut step);
            sim.run_step(&step, 16 + s, Phase::Decode);
        }
        let allocs = alloc_calls() - before;
        let m = sim.finish();
        assert!(m.tokens_out > 0, "{scenario}: audit must actually decode");
        if memory_limited {
            assert!(
                m.store_promote_ahead > 0,
                "{scenario}: the audit must exercise predictive placement"
            );
        }
        assert_eq!(
            allocs,
            0,
            "{scenario}/{}: run_step + compose_decode_into allocated {allocs} times \
             across {} steady-state steps (expected zero)",
            fw.name(),
            96 - warmup
        );
    }

    // --- digest-sink pass: tracing must not cost allocations either -------
    // The DigestSink hashes every event in place (no buffer), so a traced
    // replay of the hardest scenario (quantized tiered store) stays just
    // as allocation-free as the NullSink default. Runs inside the same
    // #[test] because this binary's counters are process-global.
    {
        let scenario = "mixtral-sim-ram16-q4";
        let (model, hw) = presets.scenario(scenario).unwrap();
        let dims = &model.sim;
        let cost = CostModel::for_scenario(&presets, scenario).unwrap();
        let trace =
            synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 16, 96, 0xa11c);
        let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
        let cfg = FrameworkCfg::paper_default(dims);
        let bundle = Framework::Dali.bundle(dims, &cost, &freq, &cfg);
        let ids: Vec<usize> = (0..8).collect();
        let store = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
        assert!(!store.is_unlimited());
        let mut sim = StepSimulator::new(
            &cost,
            bundle,
            &freq,
            dims.layers,
            dims.n_routed,
            dims.n_shared,
            7,
        )
        .with_sink(DigestSink::new())
        .with_store(store);
        let mut step = BatchStep::default();
        trace.compose_prefill_into(&ids, &mut step);
        sim.run_step(&step, 8, Phase::Prefill);
        sim.reset_metrics();
        let warmup = 32;
        for s in 0..warmup {
            trace.compose_decode_into(&ids, s, &mut step);
            sim.run_step(&step, 16 + s, Phase::Decode);
        }
        let before = alloc_calls();
        for s in warmup..trace.min_steps() {
            trace.compose_decode_into(&ids, s, &mut step);
            sim.run_step(&step, 16 + s, Phase::Decode);
        }
        let allocs = alloc_calls() - before;
        let (m, sink) = sim.finish_with_sink();
        assert!(sink.events > 0, "the digest sink must have observed events");
        assert!(m.trace_digest.is_some(), "digest must surface in RunMetrics");
        assert_eq!(
            allocs, 0,
            "{scenario}/dali+digest: traced run_step allocated {allocs} times (expected zero)"
        );
    }

    // --- multi-device pass: expert-parallel sharding is zero-alloc too ----
    // Two GPU pipelines, home-device sharding, the shared P2P fabric lane,
    // per-device residency scratch, and the device-tagged event stream all
    // ride the same pre-sized buffers: the `dev_*` scratch is reserved for
    // MAX_DEVICES * n_routed at construction, per-device lane state lives
    // in a fixed array, and P2P charging is scalar arithmetic. Steady-state
    // 2-GPU decode on the memory-limited DeepSeek-V3 cell must allocate
    // exactly as little as the single-device passes above: nothing.
    {
        let scenario = "deepseek-v3-sim-2gpu";
        let (model, hw) = presets.scenario(scenario).unwrap();
        assert_eq!(hw.num_gpus, 2, "{scenario}: preset must request two devices");
        let dims = &model.sim;
        let cost = CostModel::for_scenario(&presets, scenario).unwrap();
        let trace =
            synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 16, 96, 0xa11c);
        let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
        let cfg = FrameworkCfg::paper_default(dims);
        let bundle = Framework::Dali.bundle(dims, &cost, &freq, &cfg);
        let ids: Vec<usize> = (0..8).collect();
        let store = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
        assert!(!store.is_unlimited());
        let mut sim = StepSimulator::new(
            &cost,
            bundle,
            &freq,
            dims.layers,
            dims.n_routed,
            dims.n_shared,
            7,
        )
        .with_gpus(hw.num_gpus)
        .with_sink(DigestSink::new())
        .with_store(store);
        let mut step = BatchStep::default();
        trace.compose_prefill_into(&ids, &mut step);
        sim.run_step(&step, 8, Phase::Prefill);
        sim.reset_metrics();
        let warmup = 32;
        for s in 0..warmup {
            trace.compose_decode_into(&ids, s, &mut step);
            sim.run_step(&step, 16 + s, Phase::Decode);
        }
        let before = alloc_calls();
        for s in warmup..trace.min_steps() {
            trace.compose_decode_into(&ids, s, &mut step);
            sim.run_step(&step, 16 + s, Phase::Decode);
        }
        let allocs = alloc_calls() - before;
        let (m, sink) = sim.finish_with_sink();
        assert!(m.tokens_out > 0, "{scenario}: multi-device audit must actually decode");
        assert!(sink.events > 0, "the digest sink must have observed events");
        assert!(
            m.dev_compute_busy_ns[0] > 0 && m.dev_compute_busy_ns[1] > 0,
            "{scenario}: both devices must have computed"
        );
        assert_eq!(
            allocs, 0,
            "{scenario}/dali+2gpu: multi-device run_step allocated {allocs} times (expected zero)"
        );
    }

    // --- fault-injection pass: a flaky-nvme plan must not cost allocations -
    // The degraded cost views are precomputed once at plan install, retry /
    // backoff / stall pricing is pure arithmetic against the fault hash, and
    // flaky-nvme opens no GPU/PCIe windows, so the steady-state step under
    // injected read failures stays exactly as allocation-free as the clean
    // run. (Satellite: mixtral-sim-ram16-q4 + flaky-nvme, zero-alloc.)
    {
        let scenario = "mixtral-sim-ram16-q4";
        let (model, hw) = presets.scenario(scenario).unwrap();
        let dims = &model.sim;
        let cost = CostModel::for_scenario(&presets, scenario).unwrap();
        let trace =
            synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 16, 96, 0xa11c);
        let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
        let cfg = FrameworkCfg::paper_default(dims);
        let bundle = Framework::Dali.bundle(dims, &cost, &freq, &cfg);
        let ids: Vec<usize> = (0..8).collect();
        let store = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
        assert!(!store.is_unlimited());
        let plan =
            FaultPlan::new(presets.fault_profile("flaky-nvme").unwrap(), 0xfa17);
        let mut sim = StepSimulator::new(
            &cost,
            bundle,
            &freq,
            dims.layers,
            dims.n_routed,
            dims.n_shared,
            7,
        )
        .with_faults(plan)
        .with_store(store);
        let mut step = BatchStep::default();
        trace.compose_prefill_into(&ids, &mut step);
        sim.run_step(&step, 8, Phase::Prefill);
        sim.reset_metrics();
        let warmup = 32;
        for s in 0..warmup {
            trace.compose_decode_into(&ids, s, &mut step);
            sim.run_step(&step, 16 + s, Phase::Decode);
        }
        let before = alloc_calls();
        for s in warmup..trace.min_steps() {
            trace.compose_decode_into(&ids, s, &mut step);
            sim.run_step(&step, 16 + s, Phase::Decode);
        }
        let allocs = alloc_calls() - before;
        let m = sim.finish();
        assert!(m.tokens_out > 0, "faulted audit must actually decode");
        assert_eq!(
            allocs, 0,
            "{scenario}/dali+flaky-nvme: faulted run_step allocated {allocs} times (expected zero)"
        );
    }

    // --- serving pass: the continuous-batching tick loop is zero-alloc ----
    // Same construction as `simulate_serve` (digest sink, shared tiered
    // store), hand-built so we can split the run: warm until every request
    // has been admitted (prefill steps all behind us), then require the
    // remaining pure-decode ticks — admission checks, multi-stream compose,
    // retirement edges, lifecycle events and all — to allocate nothing.
    {
        let scenario = "mixtral-sim-ram16";
        let (model, hw) = presets.scenario(scenario).unwrap();
        let dims = &model.sim;
        let cost = CostModel::for_scenario(&presets, scenario).unwrap();
        let serve_cfg = ServeSimCfg { n_requests: 24, max_batch: 8, max_tokens: 16, ..Default::default() };
        let trace = synthetic_locality_trace(
            dims.layers,
            dims.n_routed,
            dims.top_k,
            16,
            serve_cfg.max_tokens.max(16),
            serve_cfg.seed ^ 0x7ace,
        );
        let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
        let cfg = FrameworkCfg::paper_default(dims);
        let bundle = Framework::Dali.bundle(dims, &cost, &freq, &cfg);
        let store = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
        assert!(!store.is_unlimited());
        let sim = StepSimulator::new(
            &cost,
            bundle,
            &freq,
            dims.layers,
            dims.n_routed,
            dims.n_shared,
            7,
        )
        .with_sink(DigestSink::new())
        .with_store(store);
        let mut serve = ServeSim::new(sim, &trace, serve_cfg.clone()).unwrap();
        while serve.admitted() < serve_cfg.n_requests && serve.tick() {}
        let before = alloc_calls();
        let mut ticks = 0u64;
        while serve.tick() {
            ticks += 1;
        }
        let allocs = alloc_calls() - before;
        let report = serve.finish();
        assert!(ticks > 0, "audit window must cover pure-decode ticks");
        assert_eq!(report.requests, serve_cfg.n_requests as u64);
        assert_eq!(
            report.tokens_out,
            (serve_cfg.n_requests * serve_cfg.max_tokens) as u64
        );
        assert_eq!(
            allocs, 0,
            "{scenario}/serve: steady-state serving tick allocated {allocs} times \
             across {ticks} ticks (expected zero)"
        );
    }

    // --- guarded-overload pass: the full SLO stack is zero-alloc too ------
    // Tight SLO policy on a bursty overload cell: deadline checks, queue
    // bounds, predicted-TTFT rejection, the hysteretic controller, rung
    // switches (prefetch shrink / promote pause / degraded cost view), and
    // deadline eviction all run inside the tick. Warm until admission
    // control has resolved every arrival (admitted or rejected — the
    // pending queue is drained for good), then the remaining guarded
    // decode/evict ticks must allocate nothing.
    {
        let scenario = "mixtral-sim-ram16";
        let (model, hw) = presets.scenario(scenario).unwrap();
        let dims = &model.sim;
        let cost = CostModel::for_scenario(&presets, scenario).unwrap();
        let serve_cfg = ServeSimCfg {
            arrival: ArrivalSpec::parse_spec("kind=bursty,rate=256,burst=8").unwrap(),
            n_requests: 24,
            max_batch: 4,
            max_tokens: 16,
            slo: SloSpec::named("tight").unwrap(),
            ..Default::default()
        };
        let trace = synthetic_locality_trace(
            dims.layers,
            dims.n_routed,
            dims.top_k,
            16,
            serve_cfg.max_tokens.max(16),
            serve_cfg.seed ^ 0x7ace,
        );
        let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
        let cfg = FrameworkCfg::paper_default(dims);
        let bundle = Framework::Dali.bundle(dims, &cost, &freq, &cfg);
        let store = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
        assert!(!store.is_unlimited());
        let sim = StepSimulator::new(
            &cost,
            bundle,
            &freq,
            dims.layers,
            dims.n_routed,
            dims.n_shared,
            7,
        )
        .with_sink(DigestSink::new())
        .with_store(store);
        let mut serve = ServeSim::new(sim, &trace, serve_cfg.clone()).unwrap();
        while serve.admitted() + serve.rejected() < serve_cfg.n_requests && serve.tick() {}
        let before = alloc_calls();
        let mut ticks = 0u64;
        while serve.tick() {
            ticks += 1;
        }
        let allocs = alloc_calls() - before;
        let report = serve.finish();
        assert!(ticks > 0, "guarded audit window must cover post-admission ticks");
        assert_eq!(
            report.finished + report.rejected + report.evicted,
            report.requests,
            "guarded audit cell must resolve every request"
        );
        assert!(
            report.rejected + report.evicted > 0,
            "tight SLO on an overload cell must exercise the guarded paths"
        );
        assert_eq!(
            allocs, 0,
            "{scenario}/serve+slo: guarded overload tick allocated {allocs} times \
             across {ticks} ticks (expected zero)"
        );
    }
}
