//! Property-based tests (in-tree harness; the offline build has no
//! proptest). Each property runs over hundreds of seeded random instances;
//! on failure the seed is printed for reproduction.

use dali::config::Presets;
use dali::coordinator::assignment::*;
use dali::coordinator::cache::*;
use dali::hw::{CostModel, GpuPipeline};
use dali::util::DetRng;

fn cost(model: &str) -> CostModel {
    let p = Presets::load_default().unwrap();
    CostModel::new(p.model(model).unwrap(), p.hw("local-pc").unwrap())
}

/// Run `f` over `n` seeded cases, reporting the failing seed.
fn for_seeds(n: u64, f: impl Fn(u64)) {
    for seed in 0..n {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if result.is_err() {
            panic!("property failed at seed {seed}");
        }
    }
}

fn random_ctx_parts(rng: &mut DetRng, n: usize) -> (Vec<u32>, Vec<bool>, usize) {
    let workloads: Vec<u32> = (0..n)
        .map(|_| if rng.chance(0.3) { 0 } else { rng.usize_below(64) as u32 })
        .collect();
    let resident: Vec<bool> = (0..n).map(|_| rng.chance(0.4)).collect();
    let slots = rng.usize_below(n + 1);
    (workloads, resident, slots)
}

#[test]
fn prop_all_assigners_satisfy_constraints() {
    let cms = [cost("mixtral-sim"), cost("deepseek-sim"), cost("qwen-sim")];
    for_seeds(150, |seed| {
        let mut rng = DetRng::new(seed);
        let n = 4 + rng.usize_below(28);
        let (workloads, resident, slots) = random_ctx_parts(&mut rng, n);
        let cm = &cms[seed as usize % 3];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: cm,
            gpu_free_slots: slots,
            layer: rng.usize_below(4),
            layers: 4,
            devices: None,
        };
        let assigners: Vec<Box<dyn Assigner>> = vec![
            Box::new(GreedyAssigner::new()),
            Box::new(BeamAssigner::new(2)),
            Box::new(StaticThresholdAssigner::new()),
            Box::new(AllCpuAssigner::new()),
            Box::new(ResidentOnlyAssigner::new()),
        ];
        for mut a in assigners {
            let res = a.assign(&ctx);
            assert!(res.satisfies_constraints(&ctx), "{} violated constraints", a.name());
        }
        // Layer-wise frameworks pin whole GPU layers resident by
        // construction (PinnedCache::whole_layers); its contract assumes
        // the resident mask reflects that.
        let all_res = vec![true; n];
        let ctx_lw = AssignCtx { resident: &all_res, ..ctx };
        let res = LayerWiseAssigner::new(2).assign(&ctx_lw);
        assert!(res.satisfies_constraints(&ctx_lw), "layerwise violated constraints");
    });
}

#[test]
fn prop_optimal_not_worse_than_any_heuristic() {
    let cm = cost("deepseek-sim");
    for_seeds(60, |seed| {
        let mut rng = DetRng::new(1000 + seed);
        let n = 4 + rng.usize_below(10);
        let (workloads, resident, slots) = random_ctx_parts(&mut rng, n);
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: slots,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let opt = OptimalAssigner::new().assign(&ctx).makespan_estimate(&ctx);
        let greedy = GreedyAssigner::new().assign(&ctx).makespan_estimate(&ctx);
        let beam = BeamAssigner::new(2).assign(&ctx).makespan_estimate(&ctx);
        let stat = StaticThresholdAssigner::new().assign(&ctx).makespan_estimate(&ctx);
        assert!(opt <= greedy && opt <= beam && opt <= stat);
    });
}

#[test]
fn prop_greedy_within_2x_of_optimal() {
    // List-scheduling-style bound: greedy may not match optimal but must
    // stay within 2x on every instance.
    let cm = cost("mixtral-sim");
    for_seeds(80, |seed| {
        let mut rng = DetRng::new(2000 + seed);
        let n = 4 + rng.usize_below(8);
        let (workloads, resident, _) = random_ctx_parts(&mut rng, n);
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: n,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let opt = OptimalAssigner::new().assign(&ctx).makespan_estimate(&ctx);
        let greedy = GreedyAssigner::new().assign(&ctx).makespan_estimate(&ctx);
        if opt > 0 {
            assert!(greedy as f64 <= 2.0 * opt as f64, "greedy {greedy} opt {opt}");
        }
    });
}

#[test]
fn prop_caches_hold_capacity_and_membership() {
    for_seeds(100, |seed| {
        let mut rng = DetRng::new(3000 + seed);
        let layers = 1 + rng.usize_below(4);
        let n = 4 + rng.usize_below(28);
        let cap = 1 + rng.usize_below(n);
        let caches: Vec<Box<dyn ExpertCache>> = vec![
            Box::new(WorkloadAwareCache::new(layers, n, cap, 1 + rng.usize_below(8), 1 + rng.usize_below(4), seed)),
            Box::new(LruCache::new(layers, n, cap, seed)),
            Box::new(ScoreCache::new(layers, n, cap, seed)),
        ];
        for mut c in caches {
            for step in 1..40 {
                let l = rng.usize_below(layers);
                let w: Vec<u32> = (0..n).map(|_| rng.usize_below(8) as u32).collect();
                let g: Vec<f32> = (0..n).map(|_| rng.f64() as f32).collect();
                c.observe(l, &w, &g);
                let e = rng.usize_below(n);
                let fetched = !c.is_resident(l, e);
                c.on_gpu_use(l, e, fetched);
                c.window_tick(l, step);
                // invariants
                let mask = c.resident_mask(l);
                let count = mask.iter().filter(|&&b| b).count();
                assert!(count <= cap.max(1), "{}: {count} > cap {cap}", c.name());
                for (i, &m) in mask.iter().enumerate() {
                    assert_eq!(m, c.is_resident(l, i), "mask/is_resident disagree");
                }
            }
        }
    });
}

#[test]
fn prop_pipeline_times_monotone_and_conserved() {
    for_seeds(100, |seed| {
        let mut rng = DetRng::new(4000 + seed);
        let mut p = GpuPipeline::new();
        let mut last_copy = 0;
        let mut last_compute = 0;
        let mut total_compute = 0u64;
        let mut now = 0u64;
        for _ in 0..50 {
            now += rng.usize_below(100) as u64;
            let trans = rng.usize_below(200) as u64;
            let compute = 1 + rng.usize_below(200) as u64;
            let o = p.schedule_expert(now, trans, 1, compute);
            // stream clocks never go backwards
            assert!(o.copy_end >= last_copy || trans == 0);
            assert!(o.compute_end >= last_compute);
            assert!(o.compute_end >= o.copy_end.min(o.compute_end));
            if trans > 0 {
                last_copy = o.copy_end;
            }
            last_compute = o.compute_end;
            total_compute += compute;
        }
        // busy time conservation: compute stream busy == sum of kernels
        assert_eq!(p.compute_busy, total_compute);
        // makespan >= busy time
        assert!(p.compute_free_at() >= total_compute);
    });
}

#[test]
fn prop_makespan_estimate_is_max_of_sides() {
    let cm = cost("qwen-sim");
    for_seeds(50, |seed| {
        let mut rng = DetRng::new(5000 + seed);
        let n = 8 + rng.usize_below(24);
        let (workloads, resident, _) = random_ctx_parts(&mut rng, n);
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: n,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = GreedyAssigner::new().assign(&ctx);
        let mut t_cpu = 0u64;
        let mut t_gpu = 0u64;
        for e in 0..n {
            if a.to_cpu[e] {
                t_cpu += ctx.t_cpu(e);
            }
            if a.to_gpu[e] {
                t_gpu += ctx.t_gpu(e);
            }
        }
        assert_eq!(a.makespan_estimate(&ctx), t_cpu.max(t_gpu));
    });
}
