//! Integration: live engine behaviours beyond the golden test —
//! calibration, trace recording, determinism, batch-size invariance.
//! Requires `make artifacts`.

use dali::coordinator::engine::InferenceEngine;
use dali::workload::corpus::{CorpusGen, TaskProfile};
use dali::workload::prep;


/// Shared skip probe — see `dali::runtime::live_ready`.
fn live_ready() -> bool {
    dali::runtime::live_ready()
}

#[test]
fn routing_is_batch_invariant() {
    if !live_ready() {
        return;
    }
    // A sequence's routing must not depend on what else is in the batch —
    // the property that makes trace composition exact.
    let eng = InferenceEngine::new("mixtral-sim").unwrap();
    let mut gen = CorpusGen::new(eng.dims.vocab, TaskProfile::c4(), 42);
    let prompts = gen.batch(3, 8);
    let solo = eng.run_batch(&prompts[..1].to_vec(), 4, false).unwrap();
    let batched = eng.run_batch(&prompts, 4, false).unwrap();
    assert_eq!(solo.generated[0], batched.generated[0]);
    assert_eq!(solo.decode_routes[0], batched.decode_routes[0]);
    assert_eq!(solo.prefill_routes[0], batched.prefill_routes[0]);
}

#[test]
fn generation_is_deterministic() {
    if !live_ready() {
        return;
    }
    let eng = InferenceEngine::new("mixtral-sim").unwrap();
    let mut gen = CorpusGen::new(eng.dims.vocab, TaskProfile::c4(), 7);
    let prompts = gen.batch(2, 8);
    let a = eng.run_batch(&prompts, 6, false).unwrap();
    let b = eng.run_batch(&prompts, 6, false).unwrap();
    assert_eq!(a.generated, b.generated);
}

#[test]
fn calibration_produces_usable_data() {
    if !live_ready() {
        return;
    }
    let calib = prep::ensure_calib("mixtral-sim").unwrap();
    let eng = InferenceEngine::new("mixtral-sim").unwrap();
    assert_eq!(calib.res_vec.len(), eng.dims.layers - 1);
    assert_eq!(calib.res_vec[0].len(), eng.dims.hidden);
    assert_eq!(calib.freq.len(), eng.dims.layers);
    // frequencies: each token activates top_k of n_routed experts
    for layer_freq in &calib.freq {
        let sum: f64 = layer_freq.iter().sum();
        assert!(
            (sum - eng.dims.top_k as f64).abs() < 1e-6,
            "per-layer activation mass must equal top_k, got {sum}"
        );
    }
    // residual vectors must be non-trivial
    let norm: f32 = calib.res_vec[0].iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!(norm > 1e-3, "residual vector is ~zero");
}

#[test]
fn trace_recording_matches_live_routing() {
    if !live_ready() {
        return;
    }
    let _ = prep::ensure_calib("mixtral-sim").unwrap();
    let eng = InferenceEngine::new("mixtral-sim").unwrap();
    let mut gen = CorpusGen::new(eng.dims.vocab, TaskProfile::wikitext(), 99);
    let prompts = gen.batch(2, 8);
    let out = eng.run_batch(&prompts, 5, true).unwrap();
    let trace = out.trace.unwrap();
    assert_eq!(trace.seqs.len(), 2);
    for (si, seq) in trace.seqs.iter().enumerate() {
        assert_eq!(seq.steps.len(), 5);
        for (di, step) in seq.steps.iter().enumerate() {
            for (l, rec) in step.iter().enumerate() {
                let want: Vec<u16> =
                    out.decode_routes[si][di][l].iter().map(|&e| e as u16).collect();
                assert_eq!(rec.topk, want, "seq {si} step {di} layer {l}");
                assert_eq!(rec.topk_scores.len(), want.len());
                if l + 1 < trace.layers {
                    assert_eq!(rec.pred_raw.len(), trace.top_k);
                    assert_eq!(rec.pred_res.len(), trace.top_k);
                    assert!(rec.cos_raw > -1.0 && rec.cos_raw <= 1.0);
                    assert!(rec.cos_res > -1.0 && rec.cos_res <= 1.0);
                }
            }
        }
        // prefill counts: prompt_len tokens × top_k activations per layer
        for pre in &seq.prefill {
            let total: u32 = pre.counts.iter().sum();
            assert_eq!(total as usize, seq.prompt_len * trace.top_k);
        }
    }
}

#[test]
fn residual_prediction_quality_vs_raw_features() {
    if !live_ready() {
        return;
    }
    // The paper's Table 8 premise, measured over the standard Wikitext
    // trace pool. At this scale (4 layers, raw inter-layer similarity
    // already ~0.96 vs the paper's 0.79) the mean residual vector cannot
    // improve cosine similarity — a documented deviation (EXPERIMENTS.md).
    // We therefore assert the properties the repo *does* guarantee:
    // (1) the correction is not destructive (cosine stays within a small
    // band of raw), and (2) top-1 high-workload prediction accuracy with
    // residual correction is not worse than raw features.
    let trace = prep::ensure_trace("mixtral-sim", "wikitext-sim", 16, 16, 48).unwrap();
    let (mut raw, mut res, mut n) = (0.0f64, 0.0f64, 0.0f64);
    for seq in &trace.seqs {
        for step in &seq.steps {
            for l in 0..trace.layers - 1 {
                raw += step[l].cos_raw as f64;
                res += step[l].cos_res as f64;
                n += 1.0;
            }
        }
    }
    assert!(n > 500.0, "pool too small for a stable average");
    let (raw, res) = (raw / n, res / n);
    assert!(res > raw - 0.02, "residual correction must not be destructive: {res} vs {raw}");

    // On deepseek-sim/C4 (the Table 2 configuration) residual correction
    // improves top-1 high-workload prediction with a robust margin.
    use dali::expt::common::{prefetch_accuracy, PredKind};
    let trace_ds = prep::ensure_trace("deepseek-sim", "c4-sim", 32, 16, 64).unwrap();
    let calib_ds = prep::ensure_calib("deepseek-sim").unwrap();
    let ids: Vec<usize> = (0..8).collect();
    let acc_raw = prefetch_accuracy(&trace_ds, &calib_ds, &ids, 48, PredKind::Feature, 1);
    let acc_res = prefetch_accuracy(&trace_ds, &calib_ds, &ids, 48, PredKind::Residual, 1);
    assert!(
        acc_res > acc_raw,
        "residual top-1 accuracy should beat raw features on deepseek/C4: {acc_res} vs {acc_raw}"
    );
}

#[test]
fn unequal_prompt_lengths_rejected() {
    if !live_ready() {
        return;
    }
    let eng = InferenceEngine::new("mixtral-sim").unwrap();
    let r = eng.run_batch(&[vec![1, 2, 3], vec![1, 2]], 1, false);
    assert!(r.is_err());
}
