//! Property + acceptance tests for workload-predictive tier placement
//! (`dali::store::placement`): residency stays conserved under arbitrary
//! interleavings of predictive and demand operations, budgets are never
//! exceeded, NVMe byte/time accounting conserves across promote+demote
//! cycles, and — the regression-locked acceptance criterion — predictive
//! placement strictly beats the LRU-spill baseline on the synthetic
//! locality trace under the `mixtral-sim-ram16` budget: higher GPU+host
//! tier hit rate, fewer disk misses, less demand-path NVMe time.

use dali::config::Presets;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::{replay_decode_store, replay_decode_traced};
use dali::hw::CostModel;
use dali::metrics::RunMetrics;
use dali::store::{placement, PlacementCfg, StoreCfg, TieredStore};
use dali::trace::DigestSink;
use dali::util::DetRng;
use dali::workload::trace::synthetic_locality_trace;

fn cost(model: &str, hw: &str) -> CostModel {
    let p = Presets::load_default().unwrap();
    CostModel::new(p.model(model).unwrap(), p.hw(hw).unwrap())
}

/// Run `f` over `n` seeded cases, reporting the failing seed.
fn for_seeds(n: u64, f: impl Fn(u64)) {
    for seed in 0..n {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if result.is_err() {
            panic!("property failed at seed {seed}");
        }
    }
}

#[test]
fn prop_residency_conserved_under_predictive_ops() {
    // Exactly-one-tier conservation, host-slot budgets, and the ahead
    // bookkeeping invariants hold under arbitrary interleavings of
    // promote-ahead, demand promotion, GPU admission/demotion, score
    // observation, and prediction updates.
    let c = cost("mixtral-sim", "local-pc-ram16");
    for_seeds(120, |seed| {
        let mut rng = DetRng::new(seed ^ 0x9dac);
        let layers = 1 + rng.usize_below(5);
        let n = 2 + rng.usize_below(12);
        let total = layers * n;
        let slots = 1 + rng.usize_below(total);
        let mut st = TieredStore::new(
            layers,
            n,
            StoreCfg { host_slots: slots, spill_writeback: rng.chance(0.3) },
        );
        st.set_placement(PlacementCfg {
            predictive: true,
            ahead: 1 + rng.usize_below(4),
            max_backlog: 1 + rng.usize_below(3) as u64,
            decay: 0.5,
        });
        let mut now = 0u64;
        let mut workloads = vec![0u32; n];
        let mut predicted = vec![0.0f64; n];
        for _ in 0..250 {
            let l = rng.usize_below(layers);
            let e = rng.usize_below(n);
            now += 1;
            match rng.usize_below(6) {
                0 => {
                    st.host_arrival(l, e, now, &c);
                }
                1 => {
                    st.promote_ahead(l, e, now, &c);
                }
                2 => {
                    st.host_arrival(l, e, now, &c);
                    st.admit_to_gpu(l, e);
                }
                3 => st.demote_gpu(l, e),
                4 => {
                    for w in workloads.iter_mut() {
                        *w = rng.usize_below(6) as u32;
                    }
                    st.observe_workloads(l, &workloads);
                }
                _ => {
                    for p in predicted.iter_mut() {
                        *p = rng.usize_below(8) as f64;
                    }
                    st.note_predictions(l, &predicted);
                }
            }
            st.check_invariants().unwrap();
            let (g, h, d) = st.counts();
            assert_eq!(g + h + d, total, "residency must be conserved");
            assert!(g + h <= st.host_slots(), "host budget exceeded");
            assert!(st.ahead_hits + st.ahead_misses <= st.ahead_issued);
        }
    });
}

#[test]
fn prop_nvme_accounting_conserves_across_promote_demote_cycles() {
    // Every promotion — demand or ahead — charges exactly one expert read
    // of bytes and time; demand and hidden time are consistent subsets;
    // write traffic appears iff write-back spilling is on.
    let c = cost("mixtral-sim", "local-pc-ram16");
    let expert_bytes = c.expert_bytes() as u64;
    let read_dur = c.nvme_read_time();
    for_seeds(80, |seed| {
        let mut rng = DetRng::new(seed ^ 0x0715);
        let writeback = rng.chance(0.5);
        let mut st =
            TieredStore::new(2, 8, StoreCfg { host_slots: 4, spill_writeback: writeback });
        st.set_placement(PlacementCfg::predictive(1 + rng.usize_below(3)));
        let mut predicted = vec![0.0f64; 8];
        for i in 0..120u64 {
            let l = rng.usize_below(2);
            let e = rng.usize_below(8);
            if rng.chance(0.5) {
                for p in predicted.iter_mut() {
                    *p = rng.usize_below(9) as f64;
                }
                st.note_predictions(l, &predicted);
                st.promote_ahead(l, e, i, &c);
            } else {
                st.host_arrival(l, e, i, &c);
            }
            if rng.chance(0.2) {
                st.demote_gpu(l, e);
            }
        }
        assert_eq!(st.xfer.read_bytes, st.promotions * expert_bytes);
        assert_eq!(st.xfer.reads, st.promotions);
        assert_eq!(st.xfer.read_busy, st.promotions * read_dur);
        let demand_promotions = st.promotions - st.ahead_issued;
        assert_eq!(st.demand_read_ns, demand_promotions * read_dur);
        assert!(st.overlap_hidden_ns <= st.ahead_hits * read_dur);
        if writeback {
            assert_eq!(st.xfer.write_bytes, st.spills * expert_bytes);
        } else {
            assert_eq!(st.xfer.write_bytes, 0);
        }
        st.check_invariants().unwrap();
    });
}

#[test]
fn promote_ahead_layer_never_overflows_budgets() {
    // The simrun driver path: repeated ranked promote-ahead rounds can
    // never exceed the per-round budget, the host-slot budget, or promote
    // an expert into two tiers at once.
    let c = cost("mixtral-sim", "local-pc-ram16");
    for_seeds(60, |seed| {
        let mut rng = DetRng::new(seed ^ 0xabcd);
        let layers = 2 + rng.usize_below(3);
        let n = 4 + rng.usize_below(8);
        let slots = 1 + rng.usize_below(layers * n);
        let mut st =
            TieredStore::new(layers, n, StoreCfg { host_slots: slots, ..Default::default() });
        let cfg = PlacementCfg::predictive(1 + rng.usize_below(4));
        st.set_placement(cfg);
        let mut scores = vec![0.0f64; n];
        let mut ranked: Vec<usize> = (0..n).collect();
        for round in 0..40u64 {
            let l = rng.usize_below(layers);
            for s in scores.iter_mut() {
                *s = rng.usize_below(10) as f64;
            }
            ranked.sort_unstable_by(|&a, &b| {
                scores[b].total_cmp(&scores[a]).then(a.cmp(&b))
            });
            st.note_predictions(l, &scores);
            let issued =
                placement::promote_ahead_layer(&mut st, l, &ranked, &scores, round * 3, &c);
            assert!(issued <= cfg.ahead, "per-round budget exceeded");
            st.check_invariants().unwrap();
            assert!(st.host_used() <= st.host_slots());
        }
    });
}

/// DALI bundle replay over the synthetic locality workload with the
/// `mixtral-sim-ram16` store; `predictive` toggles the placement policy
/// (false = PR 1's reactive LRU-spill baseline) and `quant_ratio` picks
/// the on-disk expert format (1.0 = fp16, the `-q4` scenarios' ratio for
/// quantized).
fn ram16_replay_fmt(predictive: bool, seed: u64, quant_ratio: f64) -> RunMetrics {
    ram16_replay_impl(predictive, seed, quant_ratio, false)
}

/// [`ram16_replay_fmt`] under a digest sink: the returned metrics carry
/// `trace_digest`, the whole-run event-stream hash.
fn ram16_digest(predictive: bool, seed: u64, quant_ratio: f64) -> u64 {
    ram16_replay_impl(predictive, seed, quant_ratio, true).trace_digest.unwrap()
}

fn ram16_replay_impl(predictive: bool, seed: u64, quant_ratio: f64, traced: bool) -> RunMetrics {
    let p = Presets::load_default().unwrap();
    let (model, hw) = p.scenario("mixtral-sim-ram16").unwrap();
    assert!(hw.is_memory_limited(&model.paper));
    let c = CostModel::new(model, hw).with_quant_ratio(quant_ratio);
    let dims = &model.sim;
    let trace = synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 16, 48, 0x7157);
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let cfg = FrameworkCfg::paper_default(dims);
    let mut bundle = Framework::Dali.bundle(dims, &c, &freq, &cfg);
    assert!(bundle.placement.predictive, "DALI defaults to predictive placement");
    if !predictive {
        bundle.placement = PlacementCfg::default();
    }
    let store = TieredStore::for_model(hw, &c, dims.layers, dims.n_routed);
    assert!(!store.is_unlimited());
    let ids: Vec<usize> = (0..8).collect();
    if traced {
        replay_decode_traced(
            &trace,
            &ids,
            40,
            &c,
            bundle,
            &freq,
            dims.n_shared,
            seed,
            Some(store),
            DigestSink::new(),
        )
        .0
    } else {
        replay_decode_store(&trace, &ids, 40, &c, bundle, &freq, dims.n_shared, seed, Some(store))
    }
}

fn ram16_replay(predictive: bool, seed: u64) -> RunMetrics {
    ram16_replay_fmt(predictive, seed, 1.0)
}

#[test]
fn predictive_placement_beats_lru_spill_on_locality_trace() {
    // ISSUE acceptance, regression-locked: on mixtral-sim-ram16 with the
    // locality trace, predictive placement strictly improves the GPU+host
    // tier hit rate and reduces demand-path NVMe time vs LRU spill.
    let lru = ram16_replay(false, 7);
    let pred = ram16_replay(true, 7);
    // the baseline must genuinely exercise the disk tier
    assert!(lru.tier_disk_misses > 0, "baseline must see disk misses");
    assert_eq!(lru.store_promote_ahead, 0, "reactive baseline never promotes ahead");
    // predictive placement actually fired and was consumed
    assert!(pred.store_promote_ahead > 0);
    assert!(pred.promote_ahead_hits > 0);
    assert!(pred.nvme_overlap_hidden_ns > 0, "NVMe latency must hide behind compute");
    // --- the acceptance inequalities ------------------------------------
    assert!(
        pred.tier_hit_rate() > lru.tier_hit_rate(),
        "GPU+host tier hit rate must strictly improve: {:.4} vs {:.4}",
        pred.tier_hit_rate(),
        lru.tier_hit_rate()
    );
    assert!(
        pred.tier_disk_misses < lru.tier_disk_misses,
        "disk misses must drop: {} vs {}",
        pred.tier_disk_misses,
        lru.tier_disk_misses
    );
    assert!(
        pred.nvme_demand_ns < lru.nvme_demand_ns,
        "demand-path NVMe time must shrink: {} vs {}",
        pred.nvme_demand_ns,
        lru.nvme_demand_ns
    );
}

#[test]
fn q4_on_disk_cuts_demand_nvme_vs_fp16() {
    // ISSUE acceptance, regression-locked: on mixtral-sim-ram16 with the
    // locality trace, the q4 on-disk format shows strictly lower demand
    // NVMe time than fp16-on-disk (the `expt ram` quant column's claim) —
    // the asymmetry is actually modeled: smaller reads on the demand
    // path, a real transcode stage on its own lane, NVMe bytes saved.
    // Holds under predictive placement and the LRU-spill baseline alike.
    let p = Presets::load_default().unwrap();
    let q4_ratio = p.quant_ratio("mixtral-sim-ram16-q4");
    assert!(q4_ratio < 1.0, "the q4 scenario must exist and be quantized");
    for predictive in [true, false] {
        let fp16 = ram16_replay_fmt(predictive, 7, 1.0);
        let q4 = ram16_replay_fmt(predictive, 7, q4_ratio);
        assert!(fp16.nvme_demand_ns > 0, "baseline must pay demand reads");
        assert_eq!(fp16.transcode_ns, 0, "fp16 on disk never transcodes");
        assert_eq!(fp16.disk_bytes_saved, 0);
        assert!(
            q4.nvme_demand_ns < fp16.nvme_demand_ns,
            "predictive={predictive}: q4 demand NVMe must be strictly lower: {} vs {}",
            q4.nvme_demand_ns,
            fp16.nvme_demand_ns
        );
        assert!(q4.transcode_ns > 0, "q4 promotions pass the transcode lane");
        assert!(q4.disk_bytes_saved > 0, "quantized reads keep bytes off NVMe");
        assert!(q4.nvme_read_bytes < fp16.nvme_read_bytes);
    }
}

#[test]
fn placement_comparison_pair_replays_bit_identically() {
    // Both sides of the comparison stay deterministic — the speedup claim
    // is meaningless if either side drifts run-to-run. The lock is a
    // whole-run trace digest per (scenario, bundle, seed): equal digests
    // mean the two replays emitted the *same event stream*, a strictly
    // stronger guarantee than the old per-metric equality (which sampled
    // a few dozen counters out of the schedule). The quantized format
    // preserves the guarantee (its transcode lane is pure virtual-time
    // bookkeeping).
    let p = Presets::load_default().unwrap();
    let q4 = p.quant_ratio("mixtral-sim-ram16-q4");
    for (predictive, quant) in [(true, 1.0), (false, 1.0), (true, q4), (false, q4)] {
        assert_eq!(
            ram16_digest(predictive, 11, quant),
            ram16_digest(predictive, 11, quant),
            "predictive={predictive} quant={quant}: replay digest must be stable"
        );
    }
    // the two compared policies must not hash to the same stream
    assert_ne!(ram16_digest(true, 11, 1.0), ram16_digest(false, 11, 1.0));
    // and the untraced default still replays metric-for-metric (digest
    // audits complement RunMetrics determinism, they don't replace it)
    assert_eq!(ram16_replay(true, 11), ram16_replay(true, 11));
}

#[test]
fn gpu_tier_census_respects_cache_budget_under_placement() {
    // Predictive promotion feeds the host tier only; the GPU tier is still
    // bounded by the cache capacity per layer.
    use dali::coordinator::simrun::{Phase, StepSimulator};
    let p = Presets::load_default().unwrap();
    let (model, hw) = p.scenario("mixtral-sim-ram16").unwrap();
    let c = CostModel::new(model, hw);
    let dims = &model.sim;
    let trace = synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 8, 32, 0x55aa);
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let cfg = FrameworkCfg::paper_default(dims);
    let bundle = Framework::Dali.bundle(dims, &c, &freq, &cfg);
    let cache_size = cfg.cache_size;
    let store = TieredStore::for_model(hw, &c, dims.layers, dims.n_routed);
    let mut sim = StepSimulator::new(
        &c,
        bundle,
        &freq,
        dims.layers,
        dims.n_routed,
        dims.n_shared,
        7,
    )
    .with_store(store);
    let ids: Vec<usize> = (0..8).collect();
    let mut step = dali::workload::trace::BatchStep::default();
    trace.compose_prefill_into(&ids, &mut step);
    sim.run_step(&step, 8, Phase::Prefill);
    for s in 0..trace.min_steps() {
        trace.compose_decode_into(&ids, s, &mut step);
        sim.run_step(&step, 16 + s, Phase::Decode);
        let st = sim.store().unwrap();
        st.check_invariants().unwrap();
        for l in 0..dims.layers {
            assert!(
                st.gpu_count_layer(l) <= cache_size,
                "step {s} layer {l}: {} GPU-primary experts exceed cache budget {cache_size}",
                st.gpu_count_layer(l)
            );
        }
    }
}
