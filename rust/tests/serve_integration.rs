//! Integration: the HTTP serving stack end-to-end — server boot, health,
//! generation, batching, metrics, error handling.
//! Requires `make artifacts` (starts a real engine).

use dali::coordinator::frameworks::Framework;
use dali::serve::batcher::BatcherCfg;
use dali::serve::http::http_call;
use dali::serve::server::serve_background;
use dali::util::json::Value;

fn start() -> String {
    let port = serve_background(
        "mixtral-sim",
        Framework::Dali,
        BatcherCfg { max_batch: 4, max_wait: std::time::Duration::from_millis(30), ..Default::default() },
    )
    .expect("server start (needs `make artifacts`)");
    format!("127.0.0.1:{port}")
}


/// Shared skip probe — see `dali::runtime::live_ready`.
fn live_ready() -> bool {
    dali::runtime::live_ready()
}

#[test]
fn serve_end_to_end() {
    if !live_ready() {
        return;
    }
    let addr = start();

    // health
    let h = http_call(&addr, "GET", "/health", None).unwrap();
    let v = Value::parse(&h).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "ok");

    // one generation
    let body = r#"{"prompt": [1, 2, 3, 4], "max_tokens": 3}"#;
    let r = http_call(&addr, "POST", "/generate", Some(body)).unwrap();
    let v = Value::parse(&r).unwrap();
    assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 3);
    assert!(v.get("sim_tokens_per_s").unwrap().as_f64().unwrap() > 0.0);

    // determinism: same prompt → same tokens
    let r2 = http_call(&addr, "POST", "/generate", Some(body)).unwrap();
    let v2 = Value::parse(&r2).unwrap();
    assert_eq!(
        v.get("tokens").unwrap().to_json(),
        v2.get("tokens").unwrap().to_json()
    );

    // concurrent clients with equal shapes get batched together
    let mut handles = vec![];
    for i in 0..4 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let body = format!(r#"{{"prompt": [{}, 2, 3, 9], "max_tokens": 2}}"#, i + 5);
            let r = http_call(&addr, "POST", "/generate", Some(&body)).unwrap();
            Value::parse(&r).unwrap().get("batch_size").unwrap().as_usize().unwrap()
        }));
    }
    let sizes: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(sizes.iter().any(|&s| s > 1), "some requests should batch: {sizes:?}");

    // metrics
    let m = http_call(&addr, "GET", "/metrics", None).unwrap();
    let v = Value::parse(&m).unwrap();
    assert!(v.get("requests").unwrap().as_u64().unwrap() >= 6);
    assert_eq!(v.get("errors").unwrap().as_u64().unwrap(), 0);

    // bad requests
    let r = http_call(&addr, "POST", "/generate", Some("{not json")).unwrap();
    assert!(r.contains("error"));
    let r = http_call(&addr, "GET", "/nope", None).unwrap();
    assert!(r.contains("not found"));
}
