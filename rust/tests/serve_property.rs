//! Chaos-composition properties for SLO-guarded serving: randomized (but
//! fully seeded) bursty arrival processes × fault profiles × SLO policies
//! thrown at the continuous-batching simulation. The suite proves the
//! overload-protection claims compositionally: every guarded run
//! terminates within a bounded tick budget, every request resolves
//! exactly once (finished + rejected + evicted == n), attainment and
//! goodput never exceed what was actually served, the same seed
//! reproduces the same digest with the full guard stack active, an
//! unlimited/observe spec is bit-transparent even under faults, and the
//! degradation ladder only ever moves one rung at a time.

use dali::config::Presets;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::StepSimulator;
use dali::fault::{FaultPlan, FaultProfile};
use dali::hw::CostModel;
use dali::metrics::ServeReport;
use dali::serve::{ArrivalSpec, OverloadController, ServeSim, ServeSimCfg, SloSpec};
use dali::store::TieredStore;
use dali::trace::DigestSink;
use dali::util::DetRng;
use dali::workload::trace::synthetic_locality_trace;

/// Run `f` over `n` seeded cases, reporting the failing seed.
fn for_seeds(n: u64, f: impl Fn(u64)) {
    for seed in 0..n {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if result.is_err() {
            panic!("property failed at seed {seed}");
        }
    }
}

/// An arbitrary-but-valid guarded SLO spec: every field stays inside
/// `SloSpec::validate`'s envelope by construction, and each protection
/// axis (TTFT budget, completion budget, queue bound, ladder) is
/// independently present or absent so their compositions are exercised.
fn random_slo(rng: &mut DetRng) -> SloSpec {
    let mut s = SloSpec::default();
    if rng.chance(0.7) {
        s.ttft_ms = (1 + rng.usize_below(400)) as f64 / 10.0; // 0.1..40 ms
    }
    if rng.chance(0.6) {
        s.total_ms = (5 + rng.usize_below(1000)) as f64 / 10.0; // 0.5..100 ms
    }
    s.jitter = rng.usize_below(50) as f64 / 100.0; // [0, 0.5)
    if rng.chance(0.5) {
        s.queue_cap = 1 + rng.usize_below(16);
    }
    if rng.chance(0.5) {
        s.hi_queue = 2 + rng.usize_below(9);
        s.lo_queue = rng.usize_below(s.hi_queue);
        s.hi_step_ms = (1 + rng.usize_below(300)) as f64 / 10.0;
        s.lo_step_ms = s.hi_step_ms / (2 + rng.usize_below(4)) as f64;
        s.dwell_up = 1 + rng.usize_below(3) as u32;
        s.dwell_down = 1 + rng.usize_below(4) as u32;
    }
    s.validate().expect("generated specs are valid by construction");
    s
}

/// An arbitrary bursty arrival process, sometimes with a heterogeneous
/// per-request length distribution.
fn random_arrival(rng: &mut DetRng) -> ArrivalSpec {
    let rate = [4.0, 64.0, 512.0][rng.usize_below(3)];
    let burst = 2 + rng.usize_below(7);
    let mut spec = format!("kind=bursty,rate={rate},burst={burst}");
    if rng.chance(0.5) {
        let len_min = 1 + rng.usize_below(4);
        let len_max = len_min + rng.usize_below(12);
        spec.push_str(&format!(",len_min={len_min},len_max={len_max}"));
    }
    ArrivalSpec::parse_spec(&spec).expect("generated arrivals are valid by construction")
}

/// One serving cell on the memory-limited scenario (tiered store +
/// digest sink, mirroring `simulate_serve`), driven tick by tick under a
/// hard termination bound instead of `run()`'s open loop.
fn run_cell(cfg: &ServeSimCfg, faults: Option<FaultPlan>, max_ticks: u64) -> ServeReport {
    let p = Presets::load_default().unwrap();
    let scenario = "mixtral-sim-ram16";
    let (model, hw) = p.scenario(scenario).unwrap();
    let dims = &model.sim;
    let cost = CostModel::for_scenario(&p, scenario).unwrap();
    let trace = synthetic_locality_trace(
        dims.layers,
        dims.n_routed,
        dims.top_k,
        16,
        cfg.max_tokens.max(cfg.arrival.len_max).max(16),
        cfg.seed ^ 0x7ace,
    );
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let fwcfg = FrameworkCfg::paper_default(dims);
    let bundle = Framework::Dali.bundle(dims, &cost, &freq, &fwcfg);
    let mut sim =
        StepSimulator::new(&cost, bundle, &freq, dims.layers, dims.n_routed, dims.n_shared, 7)
            .with_sink(DigestSink::new());
    if let Some(plan) = faults {
        sim = sim.with_faults(plan);
    }
    let store = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
    if !store.is_unlimited() {
        sim = sim.with_store(store);
    }
    let mut serve = ServeSim::new(sim, &trace, cfg.clone()).unwrap();
    let mut ticks = 0u64;
    while serve.tick() {
        ticks += 1;
        assert!(
            ticks < max_ticks,
            "serving run failed to terminate within {max_ticks} ticks \
             (rung {}, admitted {}, rejected {}, evicted {})",
            serve.rung(),
            serve.admitted(),
            serve.rejected(),
            serve.evicted()
        );
    }
    serve.finish()
}

#[test]
fn prop_guarded_chaos_cells_terminate_and_conserve_requests() {
    // Random (arrival, faults, SLO) compositions: the run terminates
    // within a generous tick bound, every request resolves exactly once,
    // and the SLO accounting never overcounts.
    for_seeds(14, |seed| {
        let mut rng = DetRng::new(seed ^ 0x510c_4a05);
        let arrival = random_arrival(&mut rng);
        let slo = random_slo(&mut rng);
        let faults = if rng.chance(0.5) {
            Some(FaultPlan::new(FaultProfile::named("flaky-nvme").unwrap(), seed ^ 0xfa17))
        } else {
            None
        };
        let cfg = ServeSimCfg {
            arrival,
            n_requests: 16,
            max_batch: 4,
            max_tokens: 6,
            slo,
            seed: seed.wrapping_mul(0x9e37_79b9).wrapping_add(0x5e11),
        };
        // every tick resolves at least nothing but makes progress through
        // arrivals/admissions; 64 ticks per request is far beyond any
        // legitimate schedule for 6-token decodes
        let r = run_cell(&cfg, faults, 64 * cfg.n_requests as u64);
        assert_eq!(
            r.finished + r.rejected + r.evicted,
            r.requests,
            "every request must resolve exactly once (spec {slo:?})"
        );
        assert!(r.slo_attained <= r.finished, "only finished requests can attain");
        assert!(r.goodput_tokens <= r.tokens_out, "goodput cannot exceed tokens served");
        assert!(r.makespan_ns > 0 || r.finished == 0);
        let att = r.slo_attainment();
        assert!(att.is_finite() && (0.0..=1.0).contains(&att));
        // same composition, same seed: bit-identical digest
        let again = run_cell(&cfg, faults, 64 * cfg.n_requests as u64);
        assert_eq!(r, again, "guarded chaos cells must reproduce bit-for-bit");
    });
}

#[test]
fn prop_disarmed_specs_are_bit_transparent_even_under_faults() {
    // A spec with enforcement off — whatever its budgets — and the
    // unlimited default must leave the event stream untouched, faults
    // included. Attainment may differ (observe mode scores deadlines);
    // the digest may not.
    for_seeds(10, |seed| {
        let mut rng = DetRng::new(seed ^ 0x0b5e_12ce);
        let arrival = random_arrival(&mut rng);
        let faults = if rng.chance(0.5) {
            Some(FaultPlan::new(FaultProfile::named("flaky-nvme").unwrap(), seed ^ 0xfa17))
        } else {
            None
        };
        let base_cfg = ServeSimCfg {
            arrival,
            n_requests: 12,
            max_batch: 4,
            max_tokens: 6,
            seed: seed.wrapping_add(0xd1_5a_12),
            ..Default::default()
        };
        let base = run_cell(&base_cfg, faults, 4096);
        let observe = SloSpec { enforce: false, ..random_slo(&mut rng) };
        let obs =
            run_cell(&ServeSimCfg { slo: observe, ..base_cfg.clone() }, faults, 4096);
        assert_eq!(
            obs.run.trace_digest, base.run.trace_digest,
            "observe-only spec {observe:?} must not change a single event"
        );
        assert_eq!((obs.rejected, obs.evicted, obs.degraded_ns), (0, 0, 0));
        let unlimited =
            run_cell(&ServeSimCfg { slo: SloSpec::default(), ..base_cfg }, faults, 4096);
        assert_eq!(unlimited, base, "the unlimited spec is the unguarded run, bit for bit");
    });
}

#[test]
fn prop_controller_moves_one_rung_at_a_time_within_bounds() {
    // Whatever the observation sequence, the ladder is monotone per
    // transition: |to - from| == 1, `to` always matches the controller's
    // rung, and the rung stays within [0, 3].
    for_seeds(25, |seed| {
        let mut rng = DetRng::new(seed ^ 0x1add_e2);
        let mut spec = random_slo(&mut rng);
        // force the queue axis on with a short escalation dwell: the
        // depth distribution below straddles the watermark roughly half
        // the time, so a dwell_up-run of hot ticks is certain within 300
        // observations and the "ladder engaged" assertion is structural,
        // not tuned
        spec.hi_queue = 2 + rng.usize_below(6);
        spec.lo_queue = rng.usize_below(spec.hi_queue);
        spec.dwell_up = 1 + rng.usize_below(2) as u32;
        spec.validate().unwrap();
        let mut ctrl = OverloadController::new(spec);
        let mut transitions = 0;
        for _ in 0..300 {
            if rng.chance(0.7) {
                ctrl.note_step(1 + rng.usize_below(60_000_000) as u64);
            }
            let depth = rng.usize_below(2 * spec.hi_queue.max(4));
            let before = ctrl.rung();
            if let Some((from, to)) = ctrl.observe(depth) {
                transitions += 1;
                assert_eq!(from, before, "transition must start at the current rung");
                assert_eq!(to, ctrl.rung(), "transition must land at the new rung");
                assert_eq!(
                    from.abs_diff(to),
                    1,
                    "the ladder moves exactly one rung per tick"
                );
            }
            assert!(ctrl.rung() <= 3, "rung escaped the ladder");
        }
        // the depth distribution straddles the watermarks, so a live
        // ladder axis should move at least once over 300 ticks
        assert!(transitions > 0, "ladder never engaged for spec {spec:?}");
    });
}
